//! The parallel GenCD iteration engine — the OpenMP `parallel for`
//! analogue (Sec. 4.2 Implementation).
//!
//! A pool of `threads` workers (the calling thread is worker 0, the
//! *leader*) runs the four-step iteration in lock-step, separated by
//! barriers (OpenMP's implicit region barriers):
//!
//! ```text
//!   leader: Select J, pick gradient + update paths, check stop,
//!           run observers, schedule screening       | workers wait
//!   ── barrier ──
//!   all: refresh dloss chunk (when precomputation wins)
//!   ── barrier ──
//!   [screen iterations only: all: full-set KKT sweep over bitmask
//!    words ── barrier ──]
//!   all: Propose over static chunk of J  (Algorithm 4)
//!   ── barrier ──
//!   leader: Accept -> J'                  (policy-dependent reduction)
//!   ── barrier ──
//!   all: Update over static chunk of J'   (Algorithm 3)
//!   [buffered mode only: ── barrier ── all: reduce z chunks]
//!   ── barrier ──
//!   leader: metrics, objective log, convergence checks
//! ```
//!
//! The Select and Accept steps are *policy objects* — any
//! [`Select`](super::select::Select) / [`Accept`](super::accept::Accept)
//! implementation, owned by the leader and invoked between barriers
//! (never concurrently). The eight named algorithms are just preset
//! pairs ([`super::algorithms`]); external policies plug in through
//! [`crate::solver::SolverBuilder`]. Per-iteration
//! [`Observer`](super::observer::Observer) hooks run in the leader's
//! planning phase; the convergence [`History`] is itself the default
//! observer rather than hardwired engine state.
//!
//! Work is divided with *static contiguous chunking* (the paper's
//! `schedule(static)`): thread t of T owns `len*t/T .. len*(t+1)/T`;
//! chunks over the dense sample arrays (`z`, `dloss`) additionally have
//! cache-line-aligned boundaries ([`crate::util::par::aligned_chunk`]).
//!
//! # Concurrency substrate
//!
//! Barriers are sense-reversing spin barriers with a parking fallback
//! ([`crate::util::par::SpinBarrier`]); phases are often sub-microsecond
//! and a mutex barrier would dominate them. The barriers provide the
//! happens-before edges between phases, and within a phase every shared
//! element has a unique writer, so the shared arrays
//! ([`super::problem::SharedState`], backed by
//! [`crate::util::atomic::SyncF64Vec`]) are accessed with *plain*
//! loads/stores everywhere except where writers genuinely collide: the
//! atomic-mode `z` scatter below. Per-thread reduction slots (best
//! proposals, work counters) are cache-padded so workers never
//! invalidate each other's lines.
//!
//! # Update paths
//!
//! The Update phase applies `z += delta_j * X_j` for every accepted j.
//! Three disciplines are configurable ([`UpdatePath`]), chosen per
//! iteration by a work heuristic when the config says `Auto`:
//!
//! * **conflict-free** — plain read+write. Legal when every `z[i]` has a
//!   unique writer: single-threaded runs, or COLORING's color classes
//!   (paper Sec. 4.2: "no need for synchronization in the Update step of
//!   the COLORING algorithm").
//! * **atomic** — `fetch_add` CAS loop per nonzero, the paper's
//!   `omp atomic`. Always safe; slow under contention.
//! * **buffered** — each worker scatters into a private dense
//!   accumulator, then (after one extra barrier) all workers fold every
//!   accumulator over disjoint cache-aligned chunks of `z` in one pass.
//!   No CAS anywhere; costs one O(n·T/T) sweep, so it wins when the
//!   scatter volume `|J'| · mean_col_nnz` reaches a machine-dependent
//!   multiple of the sample count `n`. The multiple is *fitted at
//!   startup* from the measured CAS-vs-plain-store cost ratio
//!   ([`crate::util::atomic::cas_plain_ratio`]; the seed hardwired 1.0)
//!   and reported as [`MetricsSnapshot::auto_switch_factor`].
//! * **blocked** — buffered semantics with the per-thread accumulators
//!   laid out as one stride-padded slab ([`crate::kernel::BlockedScatter`]):
//!   each thread's strip starts on its own 128-byte line with a guard
//!   line between strips, so adjacent threads never false-share even at
//!   the strip edges, and the reduce drains in line-aligned 16-element
//!   blocks that stream every accumulator through once. Same arithmetic
//!   as the buffered fold (bit-identical result); `Auto` prefers it over
//!   plain buffered whenever the SIMD kernel tier is active, and
//!   `update_path = "blocked"` forces it.
//!
//! The dense accumulators cost `n * threads` doubles. Past the
//! configured memory budget ([`EngineConfig::buffer_budget_mb`]) the
//! engine refuses that allocation and *spills*: each worker coalesces
//! its scatter into a thread-local sparse map and, after the same
//! end-of-scatter barrier the dense reduce uses (so line search still
//! sees the frozen residual), drains it with one atomic add per
//! **distinct** touched sample — repeated hits within an iteration
//! collapse to one CAS. The maps themselves are bounded too: a worker
//! whose map outgrows its per-thread share of the budget drains early
//! (atomic-visible, like the Atomic path; floored at ~1k entries —
//! roughly 32 KiB per thread — so tiny budgets don't drain after every
//! column), keeping spill mode far under the dense allocation it
//! replaced. Spilled iterations are counted in
//! [`MetricsSnapshot::spill_iters`].
//!
//! # Screening (the `screen` phase)
//!
//! With [`EngineConfig::screening`] on, the engine maintains an
//! [`ActiveSet`](crate::screen::ActiveSet) and stops paying for
//! coordinates that provably stay at zero (module docs:
//! [`crate::screen`]). Three hooks, all riding the existing barrier
//! protocol:
//!
//! * the incoming Select policy is wrapped in a
//!   [`ScreenedSelect`](crate::screen::ScreenedSelect), so *every*
//!   policy — preset or external — draws candidates from the active set;
//! * the Propose loop fuses a KKT slack test into each proposal it
//!   computes (the gradient is already in registers): a zero-weight
//!   coordinate whose slack `lam - |g_j|` clears the decaying threshold
//!   is deactivated on the spot, two flops on top of the dot product;
//! * every [`EngineConfig::kkt_every`] iterations — and always before a
//!   tolerance stop may become [`StopReason::Converged`] — a **screen
//!   phase** runs: workers re-evaluate the whole coordinate space over
//!   disjoint bitmask-word chunks (one fused `dot_col` + violation test
//!   per zero-weight column), reactivating any violator. The sweep
//!   costs one extra barrier crossing and `O(nnz / T)` per worker,
//!   amortized to `O(nnz / (T · kkt_every))` per iteration; between
//!   sweeps the screening overhead is `O(|J|)`.
//!
//! Convergence safety: the engine never reports `Converged` without a
//! sweep that reactivated nothing, i.e. every frozen coordinate
//! satisfies its KKT condition exactly at the final iterate — the
//! screened fixed point is the unscreened one. With screening off (the
//! default) none of this machinery is constructed and the iteration
//! replays the unscreened engine bit-for-bit.
//!
//! # §Perf
//!
//! `cargo bench --bench hotpath` measures every row below and writes
//! the machine-readable trail to `BENCH_hotpath.json`. **The reference
//! values here are projections for a typical 8-core x86-64 box (from
//! the per-op costs of CAS vs plain stores and futex vs spin wakeups),
//! recorded before this tree had been run under a toolchain — treat
//! them as expected orders of magnitude until a real bench run
//! refreshes the JSON** (tracked in ROADMAP Open items):
//!
//! | kernel                         | seed discipline | this PR  |
//! |--------------------------------|-----------------|----------|
//! | z-update, 1T, atomic CAS       |  ~3 ns/nnz      | unchanged (fallback) |
//! | z-update, 1T, unsync store     |  ~1 ns/nnz      | unchanged |
//! | z-update, 4T, contended CAS    | ~20 ns/nnz      | kept as fallback |
//! | z-update, 4T, buffered+reduce  |      —          | ~5 ns/nnz (≥2x vs CAS is the acceptance bar) |
//! | barrier crossing, 4T           | ~5 us (mutex)   | ~0.2 us (spin) |
//! | proposal sweep, screened 5%    | O(p) cols       | O(active) cols (~20x fewer gathers) |
//! | KKT sweep (screen phase)       |      —          | ~2 ns/nnz, every `kkt_every` iters |
//! | `dot_col`, 4-way + prefetch    | ~1.5 ns/nnz     | ~0.9 ns/nnz (`fast_kernels`, off by default) |
//! | `dot_col`, AVX2 gather+FMA     |      —          | ~0.5 ns/nnz (`--kernel auto`, runtime-dispatched, scalar fallback) |
//! | `axpy_col`, AVX2/AVX-512       |      —          | ~0.6 ns/nnz, bit-identical to the scalar scatter |
//! | KKT sweep, SIMD dot            |      —          | ~1.0 ns/nnz under a fast tier |
//! | z-update, 4T, blocked scatter  |      —          | ~4 ns/nnz (stride-padded strips, line-aligned drain) |
//!
//! Independent of the numbers, correctness is pinned by the
//! differential tests (`rust/tests/update_paths.rs`): all update paths
//! must produce identical `w` at T=1 (bit-exact) and 1e-12 agreement
//! under an 8-thread SHOTGUN run, with the `z_drift` invariant checked
//! after every path.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Arc, RwLock};

use super::accept::{Accept, AcceptContext, ThreadBest};
use super::convergence::{History, StopReason};
use super::linesearch;
use super::metrics::{Metrics, MetricsSnapshot};
use super::observer::{IterationInfo, Observer};
use super::problem::{Problem, SharedState};
use super::propose::{self, Proposal};
use super::select::Select;
use crate::event::{
    self, emit, EventSink, IterationCompleted, KktSweep, Meta, NoopSink, ProposalBatch,
    ScreenGate, SpillDrained, UpdateApplied,
};
use crate::kernel::{self, BlockedScatter, KernelChoice, KernelMode};
use crate::loss;
use crate::screen::{self, ActiveSet, ScreenedSelect, SweepKind, SweepStats};
use crate::util::atomic::{SyncCell, SyncF64Vec};
use crate::util::par::{aligned_chunk, CachePadded, DirtyChunks, SpinBarrier, DEFAULT_SPIN};
use crate::util::Timer;

/// Update-phase discipline for the shared residual vector `z` (see the
/// module docs). `Auto` picks per iteration; the forced variants exist
/// for ablations, tests and configs that know better.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdatePath {
    /// Per-iteration heuristic: conflict-free at T=1, buffered when the
    /// scatter volume reaches `n`, atomic otherwise.
    Auto,
    /// Always CAS `fetch_add` (the paper's `omp atomic`).
    Atomic,
    /// Always per-thread buffers + chunked reduce; spills to sparse
    /// per-thread maps when the dense accumulators would exceed
    /// [`EngineConfig::buffer_budget_mb`].
    Buffered,
    /// Plain load+store. Caller asserts every `z[i]` has a unique writer
    /// per Update phase (T=1, or COLORING's color classes).
    ConflictFree,
    /// Buffered semantics through the stride-padded
    /// [`crate::kernel::BlockedScatter`] slab: per-thread strips with
    /// guard lines, drained in cache-line-aligned blocks (module docs
    /// §Update paths). Spills like `Buffered` past the memory budget.
    Blocked,
}

impl UpdatePath {
    pub fn by_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "auto" => UpdatePath::Auto,
            "atomic" => UpdatePath::Atomic,
            "buffered" => UpdatePath::Buffered,
            "conflict-free" | "conflict_free" | "unsync" => UpdatePath::ConflictFree,
            "blocked" => UpdatePath::Blocked,
            other => anyhow::bail!(
                "unknown update path '{other}' (auto|atomic|buffered|conflict-free|blocked)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            UpdatePath::Auto => "auto",
            UpdatePath::Atomic => "atomic",
            UpdatePath::Buffered => "buffered",
            UpdatePath::ConflictFree => "conflict-free",
            UpdatePath::Blocked => "blocked",
        }
    }
}

/// Engine knobs (a subset of [`crate::config::SolverConfig`], resolved).
/// The Select/Accept policies are separate arguments to
/// [`solve`]/[`solve_from`] — they are stateful objects, not
/// configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub threads: usize,
    /// Sec. 4.1 refinement steps on accepted proposals.
    pub line_search_steps: usize,
    pub max_iters: usize,
    pub max_seconds: f64,
    /// Relative-improvement stop (0 disables). Applied over logged
    /// objectives, three consecutive hits required.
    pub tol: f64,
    /// Log cadence in iterations; 0 = time-based (every ~50 ms);
    /// `usize::MAX` disables the engine's own objective log entirely —
    /// no records, no divergence/tolerance stops from logging. The
    /// sharded layer uses this: its pools must never stop unilaterally
    /// (lockstep), and the global objective is logged by the shard
    /// coordinator instead.
    pub log_every: usize,
    /// Force the gradient path: `Some(true)` = always precompute dloss,
    /// `Some(false)` = always on-the-fly, `None` = per-iteration
    /// heuristic (ablation: `benches/ablations.rs`).
    pub force_dloss: Option<bool>,
    /// `z` scatter discipline for the Update phase (module docs §Update
    /// paths). `Auto` unless the caller knows better (the builder forces
    /// `ConflictFree` for COLORING).
    pub update_path: UpdatePath,
    /// Memory budget for the buffered Update path's dense per-thread
    /// accumulators (`n * threads` doubles). When they would exceed this
    /// many MiB, buffered iterations spill to sparse per-thread maps
    /// instead (module docs §Update paths).
    pub buffer_budget_mb: usize,
    /// Spin budget of the phase barrier before a waiter parks; 0 parks
    /// immediately (useful when heavily oversubscribed).
    pub barrier_spin: u32,
    /// Active-set KKT screening (module docs §Screening; default off —
    /// the unscreened iteration is replayed bit-for-bit). Requires
    /// `lam > 0` to ever deactivate anything; the builder validates.
    pub screening: bool,
    /// Full-set KKT sweep cadence in iterations when `screening` is on
    /// (the reactivation safety net; 0 disables periodic sweeps,
    /// leaving only the convergence-gate sweep — the builder rejects
    /// that, but the engine tolerates it for ablations).
    pub kkt_every: usize,
    /// Drive the periodic sweep cadence from the *measured* reactivation
    /// rate instead of the fixed `kkt_every` (module docs §Screening):
    /// a sweep that reactivates nothing doubles the interval (capped at
    /// `kkt_every ·` [`KKT_STRETCH_MAX`]), a sweep that repairs any
    /// mistake halves it (floored at 1). `kkt_every` stays the starting
    /// interval and the stretch anchor; convergence-gate sweeps are
    /// unaffected, so the Converged certificate is cadence-independent
    /// — fixed and adaptive runs land on the same fixed point.
    pub kkt_adaptive: bool,
    /// Route the cached-dloss gradient gather (and the single-worker
    /// conflict-free scatter) through the 4-way unrolled
    /// prefetching kernels ([`crate::sparse::CscMatrix::dot_col_fast`]).
    /// Off by default: the unrolled reduction re-associates floating
    /// point, and the T = 1 bit-exact differential tests pin the scalar
    /// kernels.
    pub fast_kernels: bool,
    /// SIMD tier ceiling for the fast kernels ([`crate::kernel`]):
    /// `Auto` probes the CPU once and takes the best supported tier,
    /// the named tiers clamp to it. Inert unless `fast_kernels` is on
    /// ([`kernel::resolve`]); the resolved tier is reported in
    /// [`MetricsSnapshot::kernel_tier`].
    pub kernel: KernelChoice,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            threads: 1,
            line_search_steps: 0,
            max_iters: usize::MAX,
            max_seconds: 10.0,
            tol: 0.0,
            log_every: 0,
            force_dloss: None,
            update_path: UpdatePath::Auto,
            buffer_budget_mb: 1024,
            barrier_spin: DEFAULT_SPIN,
            screening: false,
            kkt_every: 16,
            kkt_adaptive: false,
            fast_kernels: false,
            kernel: KernelChoice::Auto,
        }
    }
}

/// Upper bound of the adaptive sweep interval, as a multiple of
/// `kkt_every`: clean sweeps double the interval until it reaches
/// `kkt_every * KKT_STRETCH_MAX`, so a long-settled active set pays at
/// most 1/16th of the fixed cadence's sweep work while the safety net
/// never fully disappears.
pub const KKT_STRETCH_MAX: usize = 16;

/// Pluggable Propose backend for a whole selected block — how the
/// PJRT/HLO path (DESIGN.md §2) slots into the engine. Runs on the
/// leader, which is the *calling* thread (never a spawned one), so
/// implementations need not be `Send`; workers are parked at a barrier
/// during the call, giving it effectively exclusive access to the
/// shared arrays.
pub trait BlockProposer {
    /// Compute proposals for every `j` in `selected`, storing
    /// `delta[j]` / `phi[j]` into `state`.
    fn propose_block(
        &mut self,
        problem: &Problem,
        state: &SharedState,
        selected: &[u32],
    ) -> anyhow::Result<()>;

    fn name(&self) -> &str;
}

/// Optional leader-side hooks for a solve: a per-iteration
/// [`Observer`], a [`BlockProposer`] backend, and/or a dirty-chunk
/// tracker for the Update scatter. `Default` is "no hooks".
#[derive(Default)]
pub struct EngineHooks<'a> {
    pub observer: Option<&'a mut dyn Observer>,
    pub block_proposer: Option<&'a mut dyn BlockProposer>,
    /// When set, every Update-phase z scatter marks the chunks it
    /// writes ([`DirtyChunks::mark`] per touched row, all four update
    /// disciplines). The sharded layer reads and clears the map at
    /// reconcile boundaries to fold only touched chunks; unsharded
    /// solves leave this `None` and pay nothing.
    pub dirty: Option<&'a DirtyChunks>,
    /// Typed event stream ([`crate::event`]). `None` instantiates the
    /// engine with the static [`NoopSink`] — every emit site compiles
    /// to nothing; `Some` pays one dynamic dispatch per event, on the
    /// leader thread only.
    pub events: Option<&'a mut dyn EventSink>,
}

impl<'a> EngineHooks<'a> {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_observer(observer: &'a mut dyn Observer) -> Self {
        Self {
            observer: Some(observer),
            ..Self::default()
        }
    }

    pub fn with_block_proposer(bp: &'a mut dyn BlockProposer) -> Self {
        Self {
            block_proposer: Some(bp),
            ..Self::default()
        }
    }
}

/// Outcome of a solve.
pub struct SolveOutput {
    pub w: Vec<f64>,
    pub objective: f64,
    pub nnz: usize,
    pub history: History,
    pub metrics: MetricsSnapshot,
    pub stop: StopReason,
    pub elapsed_secs: f64,
    /// Structured failure detail when `stop` is
    /// [`StopReason::ShardFailed`] — the first shard-pool death the
    /// sharded engine observed (panic payload, barrier timeout, or
    /// poisoned peer). Always `None` for single-engine solves and for
    /// sharded solves that finished healthy.
    pub failure: Option<crate::coordinator::convergence::SolveError>,
}

/// Resolved per-iteration update discipline (the `Auto` decision).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum UpdateMode {
    ConflictFree,
    Atomic,
    Buffered,
    /// Buffered semantics through the stride-padded
    /// [`BlockedScatter`] slab, drained in line-aligned blocks.
    Blocked,
    /// Buffered semantics under the memory budget: thread-local sparse
    /// accumulation, atomic drain.
    Spill,
}

impl UpdateMode {
    /// Stable name carried by [`UpdateApplied`] events.
    fn name(&self) -> &'static str {
        match self {
            UpdateMode::ConflictFree => "conflict-free",
            UpdateMode::Atomic => "atomic",
            UpdateMode::Buffered => "buffered",
            UpdateMode::Blocked => "blocked",
            UpdateMode::Spill => "spill",
        }
    }
}

/// Iteration plan: written by the leader, read by workers. The RwLock is
/// uncontended outside phase edges (reads happen strictly after the
/// barrier following the leader's write).
struct Plan {
    selected: Vec<u32>,
    accepted: Vec<u32>,
    use_dloss: bool,
    update: UpdateMode,
    /// Propose runs on the leader via the block proposer (HLO backend);
    /// workers skip the sparse propose loop.
    hlo: bool,
    /// Screening: run a full-set KKT sweep this iteration (extra screen
    /// phase + barrier; forces a dloss refresh).
    screen_sweep: Option<SweepKind>,
    /// Screening: current deactivation threshold for the fused
    /// Propose-phase slack test and the sweep.
    screen_thresh: f64,
    stop: Option<StopReason>,
}

/// Static contiguous chunk of `0..len` owned by thread `tid` of `t` —
/// re-exported from the canonical implementation in [`crate::util::par`]
/// (the engine and the shard partitioner share one chunking helper).
pub use crate::util::par::chunk;

/// Phase barrier: compiles to nothing for single-thread runs (CCD/SCD
/// and the Fig. 2 T=1 anchors run millions of tiny iterations), a
/// [`SpinBarrier`] otherwise.
enum PhaseBarrier {
    Noop,
    Spin(SpinBarrier),
}

impl PhaseBarrier {
    fn new(threads: usize, spin: u32) -> Self {
        if threads <= 1 {
            PhaseBarrier::Noop
        } else {
            PhaseBarrier::Spin(SpinBarrier::with_spin(threads, spin))
        }
    }

    #[inline]
    fn wait(&self) {
        if let PhaseBarrier::Spin(b) = self {
            b.wait();
        }
    }

    fn poison(&self) {
        if let PhaseBarrier::Spin(b) = self {
            b.poison();
        }
    }
}

/// Poisons the phase barrier if the owning worker unwinds, so the other
/// workers panic out of their `wait` instead of deadlocking at a
/// crossing the dead thread will never reach.
struct PoisonOnPanic<'a>(&'a PhaseBarrier);

impl Drop for PoisonOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

/// Per-thread work counters: each worker owns exactly one (cache-padded)
/// slot, written with plain stores; the leader folds them into
/// [`Metrics`] while workers are parked in the Select phase.
#[derive(Clone, Copy, Debug, Default)]
struct WorkerStats {
    propose_nnz: u64,
    updates: u64,
}

/// Run GenCD from the zero vector with the given policy pair.
pub fn solve(
    problem: &Problem,
    select: impl Select + 'static,
    accept: impl Accept + 'static,
    cfg: &EngineConfig,
) -> SolveOutput {
    let state = SharedState::new(problem.n_samples(), problem.n_features());
    solve_from(
        problem,
        &state,
        Box::new(select),
        Box::new(accept),
        cfg,
        EngineHooks::none(),
    )
}

/// Run GenCD from existing state (warm start), with arbitrary Select /
/// Accept policies and optional leader-side hooks (observer, custom
/// block-propose backend, event sink).
///
/// The body is generic over the event sink: with no sink attached the
/// engine monomorphizes against [`NoopSink`] (every emit site folds
/// away — the zero-cost discipline of [`crate::event`]); with one
/// attached it runs the `&mut dyn EventSink` instantiation, one virtual
/// call per event on the leader thread.
pub fn solve_from(
    problem: &Problem,
    state: &SharedState,
    select: Box<dyn Select>,
    accept: Box<dyn Accept>,
    cfg: &EngineConfig,
    mut hooks: EngineHooks<'_>,
) -> SolveOutput {
    match hooks.events.take() {
        Some(sink) => solve_from_impl(problem, state, select, accept, cfg, hooks, sink),
        None => solve_from_impl(problem, state, select, accept, cfg, hooks, NoopSink),
    }
}

fn solve_from_impl<E: EventSink>(
    problem: &Problem,
    state: &SharedState,
    select: Box<dyn Select>,
    accept: Box<dyn Accept>,
    cfg: &EngineConfig,
    hooks: EngineHooks<'_>,
    events: E,
) -> SolveOutput {
    let threads = cfg.threads.max(1);
    let n = problem.n_samples();
    let mean_col_nnz = problem.x.mean_col_nnz();
    // Kernel mode, resolved once per solve: Reference replays the
    // scalar seed bit-for-bit; Fast(tier) routes the column gathers and
    // scatters through the dispatched SIMD kernels (crate::kernel). The
    // tier is probed here (and clamped again inside every kernel), so a
    // solve never changes tier mid-flight.
    let kmode = kernel::resolve(cfg.fast_kernels, cfg.kernel);
    // Screening: one ActiveSet shared between the Select wrapper (reads
    // on the leader), the fused Propose-phase deactivation (atomic bit
    // clears by workers) and the sweep phase (word-chunked rewrites).
    // Wrapping here — not in the builder — means every entry point
    // (driver, builder, shard pools, direct engine calls) screens every
    // policy, preset or external, identically.
    let screen: Option<Arc<ActiveSet>> = cfg
        .screening
        .then(|| Arc::new(ActiveSet::new_full(problem.n_features(), threads)));
    let select: Box<dyn Select> = match &screen {
        Some(active) => Box::new(ScreenedSelect::new(select, Arc::clone(active))),
        None => select,
    };
    // per-thread best reductions are consumed by the accept policy;
    // built-ins that ignore them opt out of the bookkeeping (§Perf)
    let need_best = accept.needs_thread_bests();
    // J' == J fast path: Update reads `selected` directly and the whole
    // Accept phase is skipped
    let passes_all = accept.passes_all();
    // Fitted Auto switch (closes the ROADMAP open item): the buffered
    // path trades |J'|·nnz̄ CAS adds for |J'|·nnz̄ plain stores plus an
    // O(n·T) reduce sweep, so it wins when
    //   |J'|·nnz̄ · (c_cas - c_plain) >= n · T · c_plain
    // i.e. when |J'|·nnz̄ >= n · T / (ratio - 1) with
    // ratio = c_cas / c_plain. The seed hardwired the factor to 1; here
    // it is derived from the startup micro-calibration
    // ([`crate::util::atomic::cas_plain_ratio`], measured once per
    // process). The measured ratio is uncontended — contention only
    // makes CAS worse — so the factor is clamped rather than trusted
    // blindly. Calibration only runs when Auto at T > 1 can actually
    // pick between the disciplines.
    let (auto_cas_ratio, auto_switch_factor) =
        if cfg.update_path == UpdatePath::Auto && threads > 1 {
            let ratio = crate::util::atomic::cas_plain_ratio();
            let factor = (threads as f64 / (ratio - 1.0).max(0.125)).clamp(0.25, 16.0);
            (ratio, factor)
        } else {
            // forced paths and T = 1 take no Auto decision; keep the
            // seed's neutral factor so reported numbers stay meaningful
            (0.0, 1.0)
        };
    // Dense buffered accumulators cost n doubles per thread; past the
    // configured budget the Spill mode takes over (no allocation here).
    let dense_fits = (n.saturating_mul(threads)).saturating_mul(8)
        <= cfg.buffer_budget_mb.saturating_mul(1024 * 1024);
    // Allocate the buffered-update accumulators only when the configured
    // path can ever pick them: forced buffered, or Auto with a
    // selection/accept volume that can reach the switch threshold.
    // Greedy-style acceptors update at most `threads` coordinates per
    // iteration and never buffer.
    let auto_wants_dense = {
        let est = accept.accept_bound(select.expected_size().ceil() as usize, threads);
        threads > 1 && est as f64 * mean_col_nnz >= auto_switch_factor * n as f64
    };
    // Under a fast kernel mode Auto prefers the stride-padded blocked
    // slab over the plain per-thread buffers (same arithmetic, better
    // locality in the drain); the two are mutually exclusive, so at
    // most one n·T-sized allocation exists.
    let wants_blocked = match cfg.update_path {
        UpdatePath::Blocked => true,
        UpdatePath::Auto => auto_wants_dense && kmode.is_fast(),
        _ => false,
    };
    let wants_buffer = match cfg.update_path {
        UpdatePath::Buffered => true,
        UpdatePath::Auto => auto_wants_dense && !kmode.is_fast(),
        UpdatePath::Atomic | UpdatePath::ConflictFree | UpdatePath::Blocked => false,
    };
    let may_buffer = wants_buffer && dense_fits;
    // The blocked slab pads each strip to a whole number of cache lines
    // (plus a guard line), so its footprint check is its own.
    let blocked_fits =
        BlockedScatter::bytes(n, threads) <= cfg.buffer_budget_mb.saturating_mul(1024 * 1024);
    let may_block = wants_blocked && blocked_fits;
    let blocked: Option<BlockedScatter> = may_block.then(|| BlockedScatter::new(n, threads));
    // Spill-mode maps cost ~32 bytes per distinct entry (key + value +
    // HashMap overhead); cap each thread's map so the spill fallback
    // cannot itself blow the budget it exists to honor — past the cap a
    // worker drains early (still correct: the drain is atomic adds).
    let spill_cap = (cfg
        .buffer_budget_mb
        .saturating_mul(1024 * 1024)
        / (threads * 32))
        .max(1024);
    // One accumulator per thread; SyncF64Vec slabs are themselves
    // 128-byte aligned, so neither the buffers nor their chunked reduce
    // share cache lines across threads.
    let buffers: Vec<SyncF64Vec> = if may_buffer {
        (0..threads).map(|_| SyncF64Vec::zeros(n)).collect()
    } else {
        Vec::new()
    };

    let plan = RwLock::new(Plan {
        selected: Vec::new(),
        accepted: Vec::new(),
        use_dloss: false,
        update: UpdateMode::Atomic,
        hlo: false,
        screen_sweep: None,
        screen_thresh: 0.0,
        stop: None,
    });
    let barrier = PhaseBarrier::new(threads, cfg.barrier_spin);
    let metrics = Metrics::default();
    let bests: Vec<CachePadded<SyncCell<ThreadBest>>> = (0..threads)
        .map(|_| CachePadded::new(SyncCell::new(ThreadBest::NONE)))
        .collect();
    let stats: Vec<CachePadded<SyncCell<WorkerStats>>> = (0..threads)
        .map(|_| CachePadded::new(SyncCell::new(WorkerStats::default())))
        .collect();
    // Sweep results: one padded slot per worker, rewritten on every
    // sweep, folded by the leader in the following plan phase.
    let sweep_stats: Vec<CachePadded<SyncCell<SweepStats>>> = (0..threads)
        .map(|_| CachePadded::new(SyncCell::new(SweepStats::default())))
        .collect();
    // Dirty-chunk hook: shared by every worker's scatter (Copy ref),
    // None outside the sharded delta-reconcile path.
    let dirty = hooks.dirty;
    // Leader-only bookkeeping, moved into the leader closure.
    let mut leader_state = LeaderState {
        selector: select,
        acceptor: accept,
        history: History::default(),
        observer: hooks.observer,
        events,
        timer: Timer::start(),
        last_log_at: -1.0,
        tol_hits: 0,
        iter: 0,
        block_proposer: hooks.block_proposer,
        select_epoch: 0,
        seen_select: Vec::new(),
        screen: ScreenLeader {
            thresh: screen::initial_threshold(problem.lam),
            last_sweep: None,
            gate_pending: false,
            sweep_interval: cfg.kkt_every.max(1),
            next_sweep_at: cfg.kkt_every.max(1),
        },
    };

    let run_worker = |tid: usize, leader: Option<&mut LeaderState<'_, E>>| {
        let mut leader = leader;
        // a panicking worker (debug assert, proposer failure) must not
        // strand its peers at the next barrier
        let _poison_guard = PoisonOnPanic(&barrier);
        // spill-mode scratch: thread-local, so the engine holds no
        // n-sized allocation per thread when over the buffer budget
        let mut spill: HashMap<u32, f64> = HashMap::new();
        // leader-only chained phase timestamps: one clock read per phase
        // boundary instead of start/stop pairs (§Perf — iterations can
        // be sub-microsecond)
        let mut mark = std::time::Instant::now();
        macro_rules! lap {
            ($counter:ident) => {
                if tid == 0 {
                    let now = std::time::Instant::now();
                    metrics
                        .$counter
                        .fetch_add((now - mark).as_nanos() as u64, Relaxed);
                    mark = now;
                }
            };
        }
        loop {
            // ---- leader: plan the iteration -------------------------
            if let Some(ls) = leader.as_deref_mut() {
                let mut p = plan.write().unwrap();
                plan_iteration(
                    problem,
                    state,
                    cfg,
                    ls,
                    &metrics,
                    &mut p,
                    mean_col_nnz,
                    &stats,
                    may_buffer,
                    may_block,
                    dense_fits,
                    auto_switch_factor,
                    screen.as_deref(),
                    &sweep_stats,
                );
            }
            barrier.wait();
            lap!(select_nanos);

            let (stop, use_dloss, hlo_mode, update_mode, selected_len, sweep, thresh) = {
                let p = plan.read().unwrap();
                (
                    p.stop,
                    p.use_dloss,
                    p.hlo,
                    p.update,
                    p.selected.len(),
                    p.screen_sweep,
                    p.screen_thresh,
                )
            };
            if stop.is_some() {
                break;
            }

            // ---- dloss refresh (parallel over samples) ---------------
            if use_dloss {
                let r = aligned_chunk(n, tid, threads);
                propose::refresh_dloss(problem, state, r.start, r.end);
            }
            barrier.wait();

            // ---- screen: full-set KKT sweep (sweep iterations only) --
            // Each worker owns a disjoint chunk of bitmask words (so the
            // whole-word rewrites never collide) and re-screens its
            // coordinates against the fresh dloss; results land in the
            // padded per-thread slots the leader folds next plan phase.
            if sweep.is_some() {
                if let Some(active) = screen.as_deref() {
                    let words = chunk(active.n_words(), tid, threads);
                    sweep_stats[tid].set(screen::sweep_range(
                        problem, state, active, thresh, words, kmode,
                    ));
                }
                barrier.wait();
                lap!(screen_nanos);
            }

            // ---- Propose (parallel over J) ---------------------------
            {
                let p = plan.read().unwrap();
                if let Some(ls) = leader.as_deref_mut() {
                    if let Some(bp) = ls.block_proposer.as_deref_mut() {
                        bp.propose_block(problem, state, &p.selected)
                            .expect("block proposer failed");
                    }
                }
                if !hlo_mode {
                    let my = chunk(p.selected.len(), tid, threads);
                    let mut best = ThreadBest::NONE;
                    let mut nnz_work = 0u64;
                    for &j in &p.selected[my] {
                        let pr =
                            propose::propose_mode(problem, state, j as usize, use_dloss, kmode);
                        store_proposal(state, &pr);
                        // fused screen: the gradient is already in hand,
                        // so the KKT slack test costs two flops. Atomic
                        // bit clear — workers may deactivate different
                        // coordinates of the same bitmask word.
                        if let Some(active) = screen.as_deref() {
                            if pr.delta == 0.0
                                && state.w.get(j as usize) == 0.0
                                && problem.lam - pr.g.abs() >= thresh
                            {
                                active.deactivate(j as usize);
                            }
                        }
                        nnz_work += problem.x.col_nnz(j as usize) as u64;
                        if need_best {
                            best.consider(j, pr.phi, pr.delta);
                        }
                    }
                    if nnz_work > 0 {
                        // own padded slot: plain RMW, no shared-line traffic
                        let mut s = stats[tid].get();
                        s.propose_nnz += nnz_work;
                        stats[tid].set(s);
                    }
                    if need_best {
                        bests[tid].set(best);
                    }
                }
            }
            barrier.wait();
            lap!(propose_nanos);

            // ---- Accept (leader) -------------------------------------
            // passes_all fast path: J' == J; the Update phase reads
            // `selected` directly, so the write lock, the policy call
            // and the copy are skipped entirely (§Perf)
            if !passes_all {
                if let Some(ls) = leader.as_deref_mut() {
                    let mut p = plan.write().unwrap();
                    if hlo_mode && need_best {
                        // derive per-chunk bests from the phi array so the
                        // accept policies behave identically to sparse mode
                        for t in 0..threads {
                            let my = chunk(p.selected.len(), t, threads);
                            let mut best = ThreadBest::NONE;
                            for &j in &p.selected[my] {
                                best.consider(
                                    j,
                                    state.phi.get(j as usize),
                                    state.delta.get(j as usize),
                                );
                            }
                            bests[t].set(best);
                        }
                    }
                    let bests_snapshot: Vec<ThreadBest> =
                        bests.iter().map(|b| b.get()).collect();
                    let Plan {
                        selected, accepted, ..
                    } = &mut *p;
                    accepted.clear();
                    ls.acceptor.accept(
                        AcceptContext {
                            bests: &bests_snapshot,
                            selected,
                            phi_of: &|j| state.phi.get(j as usize),
                            threads,
                        },
                        accepted,
                    );
                }
            }
            if tid == 0 {
                metrics.add_proposals(selected_len as u64);
            }
            barrier.wait();
            lap!(accept_nanos);

            // ---- Update (parallel over J') ---------------------------
            {
                let p = plan.read().unwrap();
                let accepted: &[u32] = if passes_all {
                    &p.selected
                } else {
                    &p.accepted
                };
                if cfg!(debug_assertions) && tid == 0 {
                    let mut seen = HashSet::with_capacity(accepted.len());
                    for &j in accepted {
                        assert!(
                            seen.insert(j),
                            "duplicate coordinate {j} in accepted set breaks the \
                             unique-writer invariant of the Update phase"
                        );
                    }
                }
                let my = chunk(accepted.len(), tid, threads);
                let mut applied = 0u64;
                for &j in &accepted[my] {
                    let j = j as usize;
                    let d0 = state.delta.get(j);
                    if d0 == 0.0 && cfg.line_search_steps == 0 {
                        continue;
                    }
                    let d = linesearch::refine(problem, state, j, d0, cfg.line_search_steps);
                    if d == 0.0 {
                        continue;
                    }
                    // unique writer for w[j] within this phase
                    state.w.add(j, d);
                    if let Some(active) = screen.as_deref() {
                        // line search reads the LIVE z, so it can move a
                        // coordinate whose frozen proposal was zero —
                        // including one the fused test (or this
                        // iteration's sweep) just deactivated; setting
                        // the bit keeps the invariant `w_j != 0 =>
                        // active`. Guarded by a plain load: nothing
                        // deactivates during the Update phase, and the
                        // common already-active case must not issue an
                        // atomic RMW on a bitmask line 64 workers'
                        // coordinates share.
                        if !active.is_active(j) {
                            active.activate(j);
                        }
                    }
                    let (rows, vals) = problem.x.col(j);
                    if let Some(dc) = dirty {
                        // sharded delta reconcile: record which chunks
                        // of z this scatter touches (idempotent marks
                        // into a cache-resident bitmap; one pass over
                        // the row indices only, shared by all four
                        // disciplines below — the buffered reduce and
                        // the spill drains write subsets of these rows)
                        for &i in rows {
                            dc.mark(i as usize);
                        }
                    }
                    match update_mode {
                        UpdateMode::ConflictFree => {
                            if let KernelMode::Fast(tier) = kmode {
                                // unique writer per z[i] (T=1 or
                                // coloring's color classes), so the
                                // dispatched scatter is legal through
                                // the raw-pointer kernel —
                                // index-disjoint raw stores are sound
                                // where two threads holding overlapping
                                // &mut slices would be UB. Every tier is
                                // bit-identical to the scalar loop (each
                                // element touched once, mul+add — no
                                // FMA, no re-association).
                                // SAFETY: the conflict-free discipline
                                // is exactly the kernel's contract.
                                unsafe {
                                    problem.x.axpy_col_ptr_tier(j, d, state.z.raw_ptr(), tier)
                                };
                            } else {
                                // unique writer per z[i] too (T=1 or
                                // coloring): plain load+store, no CAS
                                for (&i, &v) in rows.iter().zip(vals) {
                                    state.z.add(i as usize, d * v);
                                }
                            }
                        }
                        UpdateMode::Atomic => {
                            // z updates may collide across columns ->
                            // atomic add (Algorithm 3)
                            for (&i, &v) in rows.iter().zip(vals) {
                                state.z[i as usize].fetch_add(d * v, Relaxed);
                            }
                        }
                        UpdateMode::Buffered => {
                            // scatter into this thread's private dense
                            // accumulator; z itself is untouched until
                            // the reduce sub-phase below
                            let buf = &buffers[tid];
                            for (&i, &v) in rows.iter().zip(vals) {
                                buf.add(i as usize, d * v);
                            }
                        }
                        UpdateMode::Blocked => {
                            // scatter into this thread's stride-padded
                            // strip of the shared slab; same frozen-z
                            // semantics as Buffered, drained below
                            let blk = blocked.as_ref().expect("blocked slab allocated");
                            for (&i, &v) in rows.iter().zip(vals) {
                                blk.add(tid, i as usize, d * v);
                            }
                        }
                        UpdateMode::Spill => {
                            // over the buffer budget: coalesce into the
                            // thread-local sparse map; drained below.
                            // Past spill_cap entries, drain early so the
                            // map itself stays within the budget.
                            for (&i, &v) in rows.iter().zip(vals) {
                                *spill.entry(i).or_insert(0.0) += d * v;
                            }
                            if spill.len() >= spill_cap {
                                for (&i, &acc) in &spill {
                                    state.z[i as usize].fetch_add(acc, Relaxed);
                                }
                                spill.clear();
                            }
                        }
                    }
                    applied += 1;
                }
                if applied > 0 {
                    let mut s = stats[tid].get();
                    s.updates += applied;
                    stats[tid].set(s);
                }
            }
            if update_mode == UpdateMode::Spill {
                // scatters — and any same-phase line-search reads of z —
                // complete at this barrier; draining after it preserves
                // the buffered path's frozen-residual semantics (only a
                // cap-overflow early drain above is atomic-visible)
                barrier.wait();
                if !spill.is_empty() {
                    // one atomic add per *distinct* sample this thread
                    // touched; collisions across threads remain safe
                    for (&i, &acc) in &spill {
                        state.z[i as usize].fetch_add(acc, Relaxed);
                    }
                    spill.clear();
                }
            }
            if update_mode == UpdateMode::Buffered {
                // scatters done and published by this barrier ...
                barrier.wait();
                // ... then every thread folds ALL accumulators over its
                // own cache-aligned chunk of z (disjoint writers) and
                // re-zeroes them for the next iteration
                for i in aligned_chunk(n, tid, threads) {
                    let mut acc = 0.0;
                    for buf in &buffers {
                        let v = buf.get(i);
                        if v != 0.0 {
                            acc += v;
                            buf.set(i, 0.0);
                        }
                    }
                    if acc != 0.0 {
                        state.z.add(i, acc);
                    }
                }
            }
            if update_mode == UpdateMode::Blocked {
                // scatters done and published by this barrier ...
                barrier.wait();
                // ... then every thread drains ALL strips over its own
                // cache-aligned chunk of z in line-sized blocks,
                // re-zeroing the slab for the next iteration. The fold
                // order and skip-zeros arithmetic match the buffered
                // reduce exactly, so the two disciplines are
                // bit-identical.
                if let Some(blk) = blocked.as_ref() {
                    blk.drain_range(&state.z, aligned_chunk(n, tid, threads));
                }
            }
            barrier.wait();
            lap!(update_nanos);
            // loop; leader re-plans at the top
        }
    };

    if threads == 1 {
        run_worker(0, Some(&mut leader_state));
    } else {
        std::thread::scope(|scope| {
            let run_worker = &run_worker;
            for tid in 1..threads {
                scope.spawn(move || run_worker(tid, None));
            }
            run_worker(0, Some(&mut leader_state));
        });
    }

    let elapsed = leader_state.timer.elapsed_secs();
    let w = state.w_snapshot();
    let z = state.z_snapshot();
    let objective = problem.objective(&w, &z);
    let stop = plan.read().unwrap().stop.unwrap_or(StopReason::MaxIters);
    let mut snapshot = metrics.snapshot();
    snapshot.auto_cas_ratio = auto_cas_ratio;
    snapshot.auto_switch_factor = auto_switch_factor;
    snapshot.kernel_tier = kmode.name();
    if let Some(active) = &screen {
        // exact final count (the stored value lags fused deactivations
        // since the last sweep)
        snapshot.active_cols = active.popcount() as u64;
    }
    // end-of-solve phase timing — the canonical table, one code path for
    // --profile, experiment columns and bench emitters
    event::phases::emit_rows(
        &mut leader_state.events,
        Meta {
            timestamp_ticks: snapshot.iterations,
            shard: 0,
            thread: 0,
        },
        &snapshot,
    );
    SolveOutput {
        nnz: loss::nnz(&w),
        w,
        objective,
        history: leader_state.history,
        metrics: snapshot,
        stop,
        elapsed_secs: elapsed,
        failure: None,
    }
}

struct LeaderState<'a, E: EventSink> {
    selector: Box<dyn Select>,
    acceptor: Box<dyn Accept>,
    /// The default observer: records the convergence log that
    /// [`SolveOutput::history`] reports.
    history: History,
    /// User hook, run after the default observer each iteration.
    observer: Option<&'a mut dyn Observer>,
    /// Event sink, statically `NoopSink` unless a subscriber is
    /// attached (see [`solve_from`]); leader-only, like everything else
    /// in here.
    events: E,
    timer: Timer,
    last_log_at: f64,
    tol_hits: u32,
    iter: usize,
    block_proposer: Option<&'a mut dyn BlockProposer>,
    /// Epoch-stamped duplicate filter for the `passes_all` fast path
    /// (which consumes `selected` directly, bypassing the accept
    /// policy's dedup): `seen_select[j] == select_epoch` means j already
    /// appeared this iteration. O(|J|) per iteration, no hashing, no
    /// allocation after the first use.
    select_epoch: u64,
    seen_select: Vec<u64>,
    /// Screening bookkeeping (idle when `EngineConfig::screening` is
    /// off).
    screen: ScreenLeader,
}

/// Leader-side screening state: the decaying deactivation threshold and
/// the sweep pipeline (a sweep scheduled in plan N runs in iteration N
/// and is folded — counts, threshold decay, the Converged gate — in
/// plan N + 1).
struct ScreenLeader {
    thresh: f64,
    /// The sweep that ran last iteration, awaiting its fold.
    last_sweep: Option<SweepKind>,
    /// A tolerance stop fired; the next scheduled sweep decides between
    /// reactivation and `Converged`.
    gate_pending: bool,
    /// Adaptive sweep cadence (`EngineConfig::kkt_adaptive`): current
    /// interval in iterations, doubled after clean periodic sweeps
    /// (capped at `kkt_every * KKT_STRETCH_MAX`), halved after any
    /// reactivation (floored at 1). Idle under the fixed cadence.
    sweep_interval: usize,
    /// Iteration the next adaptive periodic sweep is due at.
    next_sweep_at: usize,
}

/// Resolve the configured [`UpdatePath`] into this iteration's
/// [`UpdateMode`]. `may_buffer` says whether the engine allocated the
/// dense per-thread accumulators, `may_block` whether it allocated the
/// stride-padded [`BlockedScatter`] slab (at most one of the two
/// exists); `dense_fits` whether the memory budget would even allow
/// them (when not, buffered-style work spills to sparse per-thread
/// maps). `switch_factor` is the fitted Auto-switch constant:
/// buffered-style updates engage when
/// `est_accept · mean_col_nnz >= switch_factor · n` (1.0 reproduces the
/// seed's fixed rule).
#[allow(clippy::too_many_arguments)]
fn choose_update_mode(
    path: UpdatePath,
    threads: usize,
    est_accept: usize,
    mean_col_nnz: f64,
    n: usize,
    may_buffer: bool,
    may_block: bool,
    dense_fits: bool,
    switch_factor: f64,
) -> UpdateMode {
    match path {
        UpdatePath::ConflictFree => UpdateMode::ConflictFree,
        UpdatePath::Atomic => UpdateMode::Atomic,
        UpdatePath::Buffered => {
            if may_buffer {
                UpdateMode::Buffered
            } else {
                // forced buffered semantics under the memory budget
                UpdateMode::Spill
            }
        }
        UpdatePath::Blocked => {
            if may_block {
                UpdateMode::Blocked
            } else {
                // forced blocked semantics under the memory budget
                UpdateMode::Spill
            }
        }
        UpdatePath::Auto => {
            if threads <= 1 {
                // every element trivially has a unique writer
                UpdateMode::ConflictFree
            } else if est_accept as f64 * mean_col_nnz >= switch_factor * n as f64 {
                // scatter volume reaches the sample count: the O(n)
                // reduce sweep amortizes, CAS contention does not
                if may_block {
                    UpdateMode::Blocked
                } else if may_buffer {
                    UpdateMode::Buffered
                } else if !dense_fits {
                    UpdateMode::Spill
                } else {
                    // plan-time estimate said buffering would never pay,
                    // so no accumulators exist; CAS fallback
                    UpdateMode::Atomic
                }
            } else {
                UpdateMode::Atomic
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn plan_iteration<E: EventSink>(
    problem: &Problem,
    state: &SharedState,
    cfg: &EngineConfig,
    ls: &mut LeaderState<'_, E>,
    metrics: &Metrics,
    plan: &mut Plan,
    mean_col_nnz: f64,
    stats: &[CachePadded<SyncCell<WorkerStats>>],
    may_buffer: bool,
    may_block: bool,
    dense_fits: bool,
    switch_factor: f64,
    screen: Option<&ActiveSet>,
    sweep_stats: &[CachePadded<SyncCell<SweepStats>>],
) {
    let elapsed = ls.timer.elapsed_secs();

    // ---- contention-free counter reduction -------------------------
    // Workers wrote their padded slots before the phase barrier and are
    // parked for the whole Select phase, so the leader owns every slot.
    let mut updates = 0u64;
    let mut propose_nnz = 0u64;
    for s in stats {
        let v = s.get();
        updates += v.updates;
        propose_nnz += v.propose_nnz;
    }
    metrics.updates.store(updates, Relaxed);
    metrics.propose_nnz.store(propose_nnz, Relaxed);

    // ---- fold last iteration's KKT sweep ----------------------------
    // Workers finished the sweep before the update phase's barriers, so
    // the leader owns every padded slot and the bitmask is quiescent.
    if let Some(active) = screen {
        if let Some(kind) = ls.screen.last_sweep.take() {
            let mut reactivated = 0u64;
            let mut violators = 0u64;
            let mut active_now = 0u64;
            for s in sweep_stats {
                let v = s.get();
                reactivated += v.reactivated;
                violators += v.violators;
                active_now += v.active;
            }
            metrics.kkt_passes.fetch_add(1, Relaxed);
            metrics.reactivations.fetch_add(reactivated, Relaxed);
            metrics.active_cols.store(active_now, Relaxed);
            emit!(
                ls.events,
                Meta {
                    timestamp_ticks: ls.iter as u64,
                    shard: 0,
                    thread: 0,
                },
                KktSweep {
                    violators,
                    reactivations: reactivated,
                    active: active_now,
                }
            );
            // adaptive cadence: let the measured reactivation rate set
            // the next interval — a clean sweep buys a longer one, any
            // repaired mistake snaps the net tighter. Gate sweeps are
            // convergence machinery, not cadence samples.
            if cfg.kkt_adaptive && kind == SweepKind::Periodic {
                let sl = &mut ls.screen;
                sl.sweep_interval = if reactivated == 0 {
                    (sl.sweep_interval * 2)
                        .min(cfg.kkt_every.saturating_mul(KKT_STRETCH_MAX).max(1))
                } else {
                    (sl.sweep_interval / 2).max(1)
                };
                sl.next_sweep_at = ls.iter + sl.sweep_interval;
            }
            // refresh the dense draw list for the Select wrapper's
            // cursor fallback
            active.rebuild_dense();
            // each completed sweep buys confidence: tighten the
            // deactivation threshold toward its floor
            ls.screen.thresh = screen::decay_threshold(ls.screen.thresh, problem.lam);
            if kind == SweepKind::Gate && violators == 0 && plan.stop.is_none() {
                // the gate held: every zero coordinate — frozen OR
                // active-but-undrawn — satisfies its KKT condition
                // exactly, so the screened solution is the unscreened
                // one, certified
                plan.stop = Some(StopReason::Converged);
                emit!(
                    ls.events,
                    Meta {
                        timestamp_ticks: ls.iter as u64,
                        shard: 0,
                        thread: 0,
                    },
                    ScreenGate { active: active_now }
                );
            }
            // a failed gate left every violator active (reactivating
            // frozen ones); the tolerance counter was reset when the
            // gate was scheduled, so the solve simply continues on the
            // reopened set
        }
    }

    // ---- objective log + divergence check ---------------------------
    let should_log = match cfg.log_every {
        0 => elapsed - ls.last_log_at >= 0.05 || ls.iter == 0,
        usize::MAX => false,
        every => ls.iter % every == 0,
    };
    let mut objective = None;
    let mut nnz_now = None;
    if should_log {
        let t0 = Timer::start();
        let w = state.w_snapshot();
        let z = state.z_snapshot();
        let obj = problem.objective(&w, &z);
        objective = Some(obj);
        nnz_now = Some(loss::nnz(&w));
        ls.last_log_at = elapsed;
        if !obj.is_finite() || obj > 1e12 {
            plan.stop = Some(StopReason::Diverged);
        }
        metrics
            .log_nanos
            .fetch_add((t0.elapsed_secs() * 1e9) as u64, Relaxed);
        emit!(
            ls.events,
            Meta {
                timestamp_ticks: ls.iter as u64,
                shard: 0,
                thread: 0,
            },
            IterationCompleted {
                iter: ls.iter as u64,
                updates,
                selected: plan.selected.len() as u64,
                objective,
                nnz: nnz_now.map(|v| v as u64),
            }
        );
    }

    // ---- observers ---------------------------------------------------
    // The default History observer records the log; the user observer
    // runs after it and may stop the solve. Both see the *completed*
    // iteration (`iter` = iterations finished so far).
    let info = IterationInfo {
        iter: ls.iter,
        elapsed_secs: elapsed,
        updates,
        selected: plan.selected.len(),
        objective,
        nnz: nnz_now,
        state,
    };
    let _ = ls.history.on_iteration(&info);
    if let Some(obs) = ls.observer.as_deref_mut() {
        if obs.on_iteration(&info).is_break() && plan.stop.is_none() {
            plan.stop = Some(StopReason::Observer);
        }
    }

    // ---- tolerance stop (over the history the observer just fed) ----
    if should_log && cfg.tol > 0.0 {
        let imp = ls.history.last_rel_improvement();
        if imp.abs() < cfg.tol {
            ls.tol_hits += 1;
        } else {
            ls.tol_hits = 0;
        }
        if ls.tol_hits >= 3 && plan.stop.is_none() {
            if screen.is_some() {
                // screening gates the convergence-shaped stop: schedule
                // a full-set KKT sweep instead of stopping — the next
                // plan phase declares Converged only if it reactivated
                // nothing (module docs §Screening)
                ls.screen.gate_pending = true;
                ls.tol_hits = 0;
            } else {
                plan.stop = Some(StopReason::Tolerance);
            }
        }
    }

    // ---- stop checks ------------------------------------------------
    if plan.stop.is_none() {
        if ls.iter >= cfg.max_iters {
            plan.stop = Some(StopReason::MaxIters);
        } else if elapsed >= cfg.max_seconds {
            plan.stop = Some(StopReason::MaxSeconds);
        }
    }
    if plan.stop.is_some() {
        return;
    }

    // ---- Select ------------------------------------------------------
    // the Select contract: `out` arrives cleared. A pending gate sweep
    // freezes the iterate (its iteration runs only the sweep), so the
    // draw is skipped entirely rather than taken and discarded —
    // stateful policies (cyclic pointers, RNG streams) must not advance
    // for a selection that can never be used.
    plan.selected.clear();
    let gate_now = screen.is_some() && ls.screen.gate_pending;
    if !gate_now {
        ls.selector.select(&mut plan.selected);
    }
    plan.hlo = ls.block_proposer.is_some();

    // `selected` must be duplicate-free for EVERY acceptor: the Propose
    // phase chunks it across workers and writes `delta[j]`/`phi[j]`
    // with plain stores (unique-writer invariant), and the passes_all
    // fast path additionally hands it straight to the Update phase.
    // (Accept policies dedupe the accepted side again for the other
    // cases.) The built-in selectors never repeat, but a custom one
    // may; this costs one O(|J|) stamped scan, no hashing.
    let proposed = plan.selected.len() as u64;
    if plan.selected.len() > 1 {
        if ls.seen_select.len() < problem.n_features() {
            ls.seen_select.resize(problem.n_features(), 0);
        }
        ls.select_epoch += 1;
        let epoch = ls.select_epoch;
        let seen = &mut ls.seen_select;
        plan.selected.retain(|&j| {
            let slot = &mut seen[j as usize];
            if *slot == epoch {
                false
            } else {
                *slot = epoch;
                true
            }
        });
    }
    emit!(
        ls.events,
        Meta {
            timestamp_ticks: ls.iter as u64,
            shard: 0,
            thread: 0,
        },
        ProposalBatch {
            proposed,
            deduped: plan.selected.len() as u64,
        }
    );

    // ---- screening: sweep schedule + threshold publication ----------
    plan.screen_sweep = None;
    if screen.is_some() {
        plan.screen_thresh = ls.screen.thresh;
        let periodic_due = cfg.kkt_every > 0
            && ls.iter > 0
            && if cfg.kkt_adaptive {
                ls.iter >= ls.screen.next_sweep_at
            } else {
                ls.iter % cfg.kkt_every == 0
            };
        if ls.screen.gate_pending {
            plan.screen_sweep = Some(SweepKind::Gate);
            ls.screen.gate_pending = false;
            // the iterate is frozen under the certificate: the Select
            // block above skipped the draw, so no proposals and no
            // updates land between the sweep and the stop decision — a
            // clean gate then certifies exactly the returned w
            debug_assert!(plan.selected.is_empty());
        } else if periodic_due {
            plan.screen_sweep = Some(SweepKind::Periodic);
        }
        ls.screen.last_sweep = plan.screen_sweep;
    }

    // ---- gradient-path heuristic --------------------------------------
    // Precomputing dloss costs n `ell'` evaluations; on-the-fly costs one
    // per traversed nonzero (~|J| * mean_col_nnz). Pick the cheaper.
    plan.use_dloss = match cfg.force_dloss {
        Some(forced) => forced,
        None => {
            ls.block_proposer.is_none()
                && plan.selected.len() as f64 * mean_col_nnz
                    >= problem.n_samples() as f64
        }
    };
    // a sweep reads the cached dloss for every zero-weight column — a
    // full-set pass, where precomputation always wins — so it overrides
    // the heuristic (and the force_dloss ablation knob) this iteration
    if plan.screen_sweep.is_some() {
        plan.use_dloss = true;
    }

    // ---- update-path decision -----------------------------------------
    let threads = cfg.threads.max(1);
    let est_accept = ls.acceptor.accept_bound(plan.selected.len(), threads);
    plan.update = choose_update_mode(
        cfg.update_path,
        threads,
        est_accept,
        mean_col_nnz,
        problem.n_samples(),
        may_buffer,
        may_block,
        dense_fits,
        switch_factor,
    );
    if plan.update == UpdateMode::Spill {
        metrics.spill_iters.fetch_add(1, Relaxed);
        emit!(
            ls.events,
            Meta {
                timestamp_ticks: ls.iter as u64,
                shard: 0,
                thread: 0,
            },
            SpillDrained {
                iter: ls.iter as u64,
            }
        );
    }
    emit!(
        ls.events,
        Meta {
            timestamp_ticks: ls.iter as u64,
            shard: 0,
            thread: 0,
        },
        UpdateApplied {
            path: plan.update.name(),
            cols: plan.selected.len() as u64,
        }
    );

    metrics.iterations.fetch_add(1, Relaxed);
    ls.iter += 1;
}

#[inline]
fn store_proposal(state: &SharedState, pr: &Proposal) {
    state.delta.set(pr.j, pr.delta);
    state.phi.set(pr.j, pr.phi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::accept::{self, AcceptAll, GlobalBest, GlobalTopK, ThreadGreedy};
    use crate::coordinator::select::{Cyclic, FullSet, RandomSubset};
    use crate::loss::{Logistic, Squared};
    use crate::sparse::io::Dataset;
    use crate::sparse::CooBuilder;
    use crate::util::Pcg64;
    use std::ops::ControlFlow;

    /// Small random problem with a known planted signal.
    fn make_problem(seed: u64, n: usize, k: usize, logistic: bool) -> Problem {
        let mut rng = Pcg64::seeded(seed);
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < 0.3 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let mut x = b.build();
        x.normalize_columns();
        let wstar: Vec<f64> = (0..k)
            .map(|j| if j < 3 { 1.5 } else { 0.0 })
            .collect();
        let scores = x.matvec(&wstar);
        let y: Vec<f64> = if logistic {
            scores.iter().map(|&s| if s > 0.0 { 1.0 } else { -1.0 }).collect()
        } else {
            scores
        };
        let loss: Box<dyn crate::loss::Loss> =
            if logistic { Box::new(Logistic) } else { Box::new(Squared) };
        Problem::new(
            Dataset {
                x,
                y,
                name: "t".into(),
            },
            loss,
            1e-3,
        )
    }

    fn cfg(threads: usize, iters: usize) -> EngineConfig {
        EngineConfig {
            threads,
            max_iters: iters,
            max_seconds: 30.0,
            ..Default::default()
        }
    }

    #[test]
    fn ccd_descends_squared() {
        let p = make_problem(1, 24, 10, false);
        let sel = Cyclic {
            next: 0,
            k: p.n_features(),
        };
        let out = solve(&p, sel, AcceptAll, &cfg(1, 200));
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first * 0.5, "{} -> {}", first, out.objective);
        assert_eq!(out.stop, StopReason::MaxIters);
        assert_eq!(out.metrics.iterations, 200);
    }

    #[test]
    fn shotgun_multithreaded_descends_logistic() {
        let p = make_problem(2, 32, 16, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(3),
            k: p.n_features(),
            size: 4,
        };
        let out = solve(&p, sel, AcceptAll, &cfg(4, 300));
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{} -> {}", first, out.objective);
        // z must remain consistent with w after all the atomic updates
        let state = SharedState::from_warm_start(&p, &out.w);
        let z = state.z_snapshot();
        let obj = p.objective(&out.w, &z);
        assert!((obj - out.objective).abs() < 1e-6);
    }

    #[test]
    fn thread_greedy_accepts_at_most_one_per_thread() {
        let p = make_problem(4, 24, 12, true);
        let threads = 3;
        let sel = RandomSubset {
            rng: Pcg64::seeded(5),
            k: p.n_features(),
            size: 9,
        };
        let out = solve(&p, sel, ThreadGreedy, &cfg(threads, 50));
        assert!(out.metrics.updates <= 50 * threads as u64);
        assert!(out.metrics.accept_rate() <= threads as f64 / 9.0 + 1e-9);
    }

    #[test]
    fn greedy_single_update_per_iteration() {
        let p = make_problem(6, 20, 8, false);
        let sel = FullSet { k: p.n_features() };
        let out = solve(&p, sel, GlobalBest, &cfg(2, 40));
        assert!(out.metrics.updates <= 40);
        assert!(out.objective <= out.history.records[0].objective);
    }

    #[test]
    fn topk_bounded() {
        let p = make_problem(7, 20, 12, true);
        let sel = FullSet { k: p.n_features() };
        let out = solve(&p, sel, GlobalTopK { k: 3 }, &cfg(2, 30));
        assert!(out.metrics.updates <= 90);
    }

    #[test]
    fn deterministic_single_thread() {
        let p = make_problem(8, 16, 8, true);
        let mk = || {
            let sel = RandomSubset {
                rng: Pcg64::seeded(9),
                k: p.n_features(),
                size: 3,
            };
            solve(&p, sel, AcceptAll, &cfg(1, 100))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.w, b.w);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn dloss_paths_equivalent() {
        let p = make_problem(10, 20, 10, true);
        let run = |force: Option<bool>| {
            let sel = Cyclic {
                next: 0,
                k: p.n_features(),
            };
            let mut c = cfg(1, 60);
            c.force_dloss = force;
            solve(&p, sel, AcceptAll, &c)
        };
        let a = run(Some(true));
        let b = run(Some(false));
        for (x, y) in a.w.iter().zip(&b.w) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn max_seconds_stops() {
        let p = make_problem(11, 16, 8, true);
        let sel = FullSet { k: p.n_features() };
        let mut c = cfg(2, usize::MAX);
        c.max_seconds = 0.2;
        let out = solve(&p, sel, GlobalBest, &c);
        assert_eq!(out.stop, StopReason::MaxSeconds);
        assert!(out.elapsed_secs < 5.0);
    }

    #[test]
    fn tolerance_stops() {
        let p = make_problem(12, 16, 8, false);
        let sel = Cyclic {
            next: 0,
            k: p.n_features(),
        };
        let mut c = cfg(1, usize::MAX);
        c.max_seconds = 20.0;
        c.tol = 1e-10;
        c.log_every = 10;
        let out = solve(&p, sel, AcceptAll, &c);
        assert_eq!(out.stop, StopReason::Tolerance);
    }

    #[test]
    fn line_search_accelerates_convergence() {
        let p = make_problem(13, 30, 10, true);
        let run = |steps: usize| {
            let sel = Cyclic {
                next: 0,
                k: p.n_features(),
            };
            let mut c = cfg(1, 50);
            c.line_search_steps = steps;
            solve(&p, sel, AcceptAll, &c)
        };
        let plain = run(0);
        let refined = run(20);
        assert!(
            refined.objective <= plain.objective + 1e-12,
            "{} vs {}",
            refined.objective,
            plain.objective
        );
    }

    #[test]
    fn z_consistency_under_concurrency() {
        // many threads, many iterations: incremental z must not drift
        let p = make_problem(14, 40, 24, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(15),
            k: p.n_features(),
            size: 8,
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let c = cfg(8, 200);
        solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::all(),
            &c,
            EngineHooks::none(),
        );
        assert!(state.z_drift(&p) < 1e-8, "drift {}", state.z_drift(&p));
    }

    #[test]
    fn buffered_path_consistent_multithread() {
        // forced buffered updates under real contention: z stays
        // consistent with w and the solve still descends
        let p = make_problem(16, 48, 24, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(17),
            k: p.n_features(),
            size: 8,
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let mut c = cfg(4, 200);
        c.update_path = UpdatePath::Buffered;
        let out = solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::all(),
            &c,
            EngineHooks::none(),
        );
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert!(state.z_drift(&p) < 1e-8, "drift {}", state.z_drift(&p));
        assert_eq!(out.metrics.spill_iters, 0, "dense buffers fit the budget");
    }

    #[test]
    fn buffered_with_line_search_and_thread_greedy() {
        // forced buffered path composes with line search and a
        // non-All acceptor (accepted list path, not the fast path)
        let p = make_problem(18, 32, 16, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(19),
            k: p.n_features(),
            size: 8,
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let mut c = cfg(3, 80);
        c.update_path = UpdatePath::Buffered;
        c.line_search_steps = 5;
        let out = solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::thread_greedy(),
            &c,
            EngineHooks::none(),
        );
        assert!(out.objective.is_finite());
        assert!(state.z_drift(&p) < 1e-8, "drift {}", state.z_drift(&p));
    }

    #[test]
    fn zero_budget_spills_and_stays_consistent() {
        // buffer_budget_mb = 0 refuses the dense accumulators: forced
        // buffered runs must take the spill path and remain correct
        let p = make_problem(20, 48, 24, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(21),
            k: p.n_features(),
            size: 8,
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let mut c = cfg(4, 200);
        c.update_path = UpdatePath::Buffered;
        c.buffer_budget_mb = 0;
        let out = solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::all(),
            &c,
            EngineHooks::none(),
        );
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert!(state.z_drift(&p) < 1e-8, "drift {}", state.z_drift(&p));
        assert_eq!(
            out.metrics.spill_iters, out.metrics.iterations,
            "every iteration should have spilled"
        );
    }

    #[test]
    fn observer_early_stop_and_cadence() {
        let p = make_problem(22, 24, 12, true);
        let sel = Cyclic {
            next: 0,
            k: p.n_features(),
        };
        let mut calls = 0usize;
        let mut last_iter = 0usize;
        let obs = |info: &IterationInfo<'_>| {
            calls += 1;
            last_iter = info.iter;
            if info.iter >= 25 {
                ControlFlow::Break(())
            } else {
                ControlFlow::Continue(())
            }
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let mut obs_box = obs;
        let out = solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::all(),
            &cfg(1, 1000),
            EngineHooks::with_observer(&mut obs_box),
        );
        assert_eq!(out.stop, StopReason::Observer);
        assert_eq!(out.metrics.iterations, 25);
        assert_eq!(last_iter, 25, "observer sees the completed count");
        assert_eq!(calls, 26, "one call per planning step incl. iter 0");
    }

    #[test]
    fn observer_sees_logged_objective_and_state() {
        let p = make_problem(23, 24, 12, false);
        let sel = Cyclic {
            next: 0,
            k: p.n_features(),
        };
        let mut logged = 0usize;
        let mut unlogged = 0usize;
        let mut obs = |info: &IterationInfo<'_>| {
            match info.objective {
                Some(obj) => {
                    logged += 1;
                    assert!(obj.is_finite());
                    assert!(info.nnz.is_some());
                    // state is readable while workers are parked
                    assert_eq!(info.state.w_snapshot().len(), 12);
                }
                None => unlogged += 1,
            }
            ControlFlow::Continue(())
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let mut c = cfg(1, 40);
        c.log_every = 10;
        solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::all(),
            &c,
            EngineHooks::with_observer(&mut obs),
        );
        assert!(logged >= 4, "log_every=10 over 40 iters: {logged}");
        assert!(unlogged > 0);
    }

    #[test]
    fn update_mode_choice() {
        use super::UpdateMode as M;
        use super::UpdatePath as P;
        // forced paths are forced
        assert_eq!(
            choose_update_mode(P::Atomic, 8, 1000, 50.0, 100, true, false, true, 1.0),
            M::Atomic
        );
        assert_eq!(
            choose_update_mode(P::ConflictFree, 8, 1000, 50.0, 100, false, false, true, 1.0),
            M::ConflictFree
        );
        assert_eq!(
            choose_update_mode(P::Buffered, 1, 1, 1.0, 100, true, false, true, 1.0),
            M::Buffered
        );
        assert_eq!(
            choose_update_mode(P::Blocked, 1, 1, 1.0, 100, false, true, true, 1.0),
            M::Blocked
        );
        // forced buffered/blocked past the budget spill
        assert_eq!(
            choose_update_mode(P::Buffered, 4, 200, 10.0, 1000, false, false, false, 1.0),
            M::Spill
        );
        assert_eq!(
            choose_update_mode(P::Blocked, 4, 200, 10.0, 1000, false, false, false, 1.0),
            M::Spill
        );
        // auto: single thread is conflict-free
        assert_eq!(
            choose_update_mode(P::Auto, 1, 1000, 50.0, 100, true, false, true, 1.0),
            M::ConflictFree
        );
        // auto: small scatter volume stays atomic
        assert_eq!(
            choose_update_mode(P::Auto, 4, 2, 10.0, 1000, true, false, true, 1.0),
            M::Atomic
        );
        // auto: scatter volume >= factor·n flips to buffered (when
        // allocated), preferring the blocked slab when it exists
        assert_eq!(
            choose_update_mode(P::Auto, 4, 200, 10.0, 1000, true, false, true, 1.0),
            M::Buffered
        );
        assert_eq!(
            choose_update_mode(P::Auto, 4, 200, 10.0, 1000, false, true, true, 1.0),
            M::Blocked
        );
        assert_eq!(
            choose_update_mode(P::Auto, 4, 200, 10.0, 1000, false, false, true, 1.0),
            M::Atomic
        );
        // auto over the budget: spill rather than CAS-per-nnz
        assert_eq!(
            choose_update_mode(P::Auto, 4, 200, 10.0, 1000, false, false, false, 1.0),
            M::Spill
        );
        // the fitted factor moves the switch point: the same scatter
        // volume stays atomic under a high factor and buffers under a
        // low one
        assert_eq!(
            choose_update_mode(P::Auto, 4, 200, 10.0, 1000, true, false, true, 4.0),
            M::Atomic
        );
        assert_eq!(
            choose_update_mode(P::Auto, 4, 40, 10.0, 1000, true, false, true, 0.25),
            M::Buffered
        );
    }

    #[test]
    fn auto_calibration_exposed_in_metrics() {
        // a multi-threaded Auto solve reports the measured CAS ratio and
        // the switch factor derived from it; forced paths report the
        // neutral constants
        let p = make_problem(30, 32, 16, true);
        let sel = || RandomSubset {
            rng: Pcg64::seeded(31),
            k: p.n_features(),
            size: 4,
        };
        let auto = solve(&p, sel(), AcceptAll, &cfg(4, 20));
        assert!(
            auto.metrics.auto_cas_ratio >= 1.0,
            "ratio {} not calibrated",
            auto.metrics.auto_cas_ratio
        );
        assert!(
            (0.25..=16.0).contains(&auto.metrics.auto_switch_factor),
            "factor {} outside clamp",
            auto.metrics.auto_switch_factor
        );
        let mut forced = cfg(4, 20);
        forced.update_path = UpdatePath::Atomic;
        let atomic = solve(&p, sel(), AcceptAll, &forced);
        assert_eq!(atomic.metrics.auto_cas_ratio, 0.0);
        assert_eq!(atomic.metrics.auto_switch_factor, 1.0);
    }

    #[test]
    fn screening_prunes_and_still_descends() {
        // l1-heavy problem: most coordinates stay at zero, screening
        // must shrink the active set below k without hurting descent
        let p = make_problem(40, 60, 24, false);
        let run = |screening: bool| {
            // GREEDY (full selection, single best accepted): every
            // active coordinate is proposed each iteration, so the
            // saved proposal work is directly visible in propose_nnz —
            // and a deactivated coordinate always had phi = 0, so the
            // screened greedy trajectory matches the unscreened one
            let sel = FullSet { k: p.n_features() };
            let mut c = cfg(1, 600);
            c.screening = screening;
            c.kkt_every = 16;
            solve(&p, sel, GlobalBest, &c)
        };
        let plain = run(false);
        let screened = run(true);
        assert!(
            (plain.objective - screened.objective).abs() < 1e-7,
            "screened {} vs plain {}",
            screened.objective,
            plain.objective
        );
        assert_eq!(plain.metrics.active_cols, 0, "off => no active-set report");
        assert!(
            screened.metrics.active_cols > 0
                && (screened.metrics.active_cols as usize) < p.n_features(),
            "active set must shrink below k: {} of {}",
            screened.metrics.active_cols,
            p.n_features()
        );
        assert!(
            screened.metrics.active_cols >= screened.nnz as u64,
            "the support can never be deactivated"
        );
        assert!(screened.metrics.kkt_passes >= 1);
        assert!(screened.metrics.propose_nnz < plain.metrics.propose_nnz,
            "screening must reduce proposal work");
    }

    #[test]
    fn screening_gates_tolerance_into_converged() {
        let p = make_problem(41, 30, 12, false);
        let sel = Cyclic {
            next: 0,
            k: p.n_features(),
        };
        let mut c = cfg(1, usize::MAX);
        c.max_seconds = 30.0;
        c.tol = 1e-10;
        c.log_every = 10;
        c.screening = true;
        c.kkt_every = 8;
        let out = solve(&p, sel, AcceptAll, &c);
        assert_eq!(out.stop, StopReason::Converged);
        assert!(out.metrics.kkt_passes >= 1, "the gate sweep must have run");
        // the certificate: no frozen coordinate violates KKT at the end
        let kkt = crate::coordinator::kkt::check(&p, &out.w, 1e-8);
        assert!(
            kkt.max_violation < 1e-4,
            "converged iterate far from stationary: {kkt:?}"
        );
    }

    #[test]
    fn screening_multithreaded_consistent() {
        // fused deactivations are atomic bit clears: 4 workers screening
        // concurrently must keep z consistent and the support active
        let p = make_problem(42, 48, 24, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(43),
            k: p.n_features(),
            size: 8,
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let mut c = cfg(4, 400);
        c.screening = true;
        c.kkt_every = 10;
        let out = solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::all(),
            &c,
            EngineHooks::none(),
        );
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert!(state.z_drift(&p) < 1e-8, "drift {}", state.z_drift(&p));
        assert!(out.metrics.active_cols >= out.nnz as u64);
    }

    #[test]
    fn screening_with_line_search_keeps_support_active() {
        // line search reads the live z, so it can land a nonzero step
        // on a coordinate deactivated earlier in the same iteration —
        // the update-site reactivation must preserve w != 0 => active
        let p = make_problem(45, 40, 16, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(46),
            k: p.n_features(),
            size: 6,
        };
        let mut c = cfg(2, 500);
        c.screening = true;
        c.kkt_every = 10;
        c.line_search_steps = 20;
        let out = solve(&p, sel, AcceptAll, &c);
        assert!(out.objective.is_finite());
        assert!(
            out.metrics.active_cols >= out.nnz as u64,
            "a nonzero-weight coordinate left the active set: active = {}, nnz = {}",
            out.metrics.active_cols,
            out.nnz
        );
    }

    #[test]
    fn adaptive_kkt_matches_fixed_cadence() {
        // the satellite's differential bar: adaptive sweep cadence must
        // land on the same certified fixed point as the fixed cadence —
        // both gate Converged through a clean sweep, so the objectives
        // agree to 1e-12
        let p = make_problem(50, 30, 12, false);
        let run = |adaptive: bool| {
            let sel = Cyclic {
                next: 0,
                k: p.n_features(),
            };
            let mut c = cfg(1, usize::MAX);
            c.max_seconds = 30.0;
            c.tol = 1e-10;
            c.log_every = 10;
            c.screening = true;
            c.kkt_every = 8;
            c.kkt_adaptive = adaptive;
            solve(&p, sel, AcceptAll, &c)
        };
        let fixed = run(false);
        let adaptive = run(true);
        assert_eq!(fixed.stop, StopReason::Converged);
        assert_eq!(adaptive.stop, StopReason::Converged);
        assert!(
            (fixed.objective - adaptive.objective).abs() < 1e-12,
            "fixed {} vs adaptive {}",
            fixed.objective,
            adaptive.objective
        );
        assert!(adaptive.metrics.kkt_passes >= 1);
    }

    #[test]
    fn adaptive_kkt_stretches_interval_when_quiet() {
        // a long run on a settled problem: the adaptive cadence must
        // run strictly fewer periodic sweeps than the fixed one
        let p = make_problem(51, 40, 16, false);
        let run = |adaptive: bool| {
            let sel = FullSet { k: p.n_features() };
            let mut c = cfg(1, 600);
            c.screening = true;
            c.kkt_every = 8;
            c.kkt_adaptive = adaptive;
            solve(&p, sel, GlobalBest, &c)
        };
        let fixed = run(false);
        let adaptive = run(true);
        assert!(
            adaptive.metrics.kkt_passes < fixed.metrics.kkt_passes,
            "adaptive {} sweeps vs fixed {}",
            adaptive.metrics.kkt_passes,
            fixed.metrics.kkt_passes
        );
        assert!(
            (fixed.objective - adaptive.objective).abs() < 1e-9,
            "{} vs {}",
            fixed.objective,
            adaptive.objective
        );
    }

    #[test]
    fn dirty_hook_covers_every_touched_sample() {
        // every z element the solve moved must sit in a marked chunk —
        // the contract the sharded delta reconcile relies on
        use crate::util::par::{DirtyChunks, DIRTY_CHUNK_ELEMS};
        let p = make_problem(52, 48, 20, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(53),
            k: p.n_features(),
            size: 6,
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let d = DirtyChunks::new(p.n_samples());
        let out = solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::all(),
            &cfg(2, 150),
            EngineHooks {
                dirty: Some(&d),
                ..Default::default()
            },
        );
        assert!(out.metrics.updates > 0);
        assert!(d.count() > 0, "a descending solve must dirty something");
        for (i, z) in state.z_snapshot().iter().enumerate() {
            if *z != 0.0 {
                assert!(
                    d.is_dirty(i / DIRTY_CHUNK_ELEMS),
                    "z[{i}] changed but its chunk is clean"
                );
            }
        }
    }

    #[test]
    fn fast_kernels_agree_with_scalar_engine() {
        // the unrolled gather re-associates the reduction, so no
        // bit-exactness — but the solve must land on the same optimum
        let p = make_problem(44, 40, 16, false);
        let run = |fast: bool, dloss: bool| {
            let sel = Cyclic {
                next: 0,
                k: p.n_features(),
            };
            let mut c = cfg(1, 2000);
            c.fast_kernels = fast;
            // exercise both unrolled gradient paths: the cached-dloss
            // dot and the on-the-fly ell' gather
            c.force_dloss = Some(dloss);
            solve(&p, sel, AcceptAll, &c)
        };
        for dloss in [true, false] {
            let scalar = run(false, dloss);
            let fast = run(true, dloss);
            assert!(
                (scalar.objective - fast.objective).abs() < 1e-9,
                "dloss={dloss}: {} vs {}",
                scalar.objective,
                fast.objective
            );
            for (a, b) in scalar.w.iter().zip(&fast.w) {
                assert!((a - b).abs() < 1e-7, "dloss={dloss}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn update_path_names_roundtrip() {
        for p in [
            UpdatePath::Auto,
            UpdatePath::Atomic,
            UpdatePath::Buffered,
            UpdatePath::ConflictFree,
            UpdatePath::Blocked,
        ] {
            assert_eq!(UpdatePath::by_name(p.name()).unwrap(), p);
        }
        assert!(UpdatePath::by_name("magic").is_err());
    }

    #[test]
    fn blocked_path_matches_buffered_bitwise() {
        // the blocked drain replays the buffered fold arithmetic over a
        // stride-padded slab: same seed, same selection stream, the two
        // disciplines must produce bit-identical iterates — and both
        // must keep z consistent under real multi-thread contention
        let p = make_problem(60, 48, 24, true);
        let run = |path: UpdatePath| {
            let sel = RandomSubset {
                rng: Pcg64::seeded(61),
                k: p.n_features(),
                size: 8,
            };
            let state = SharedState::new(p.n_samples(), p.n_features());
            let mut c = cfg(4, 200);
            c.update_path = path;
            let out = solve_from(
                &p,
                &state,
                Box::new(sel),
                accept::all(),
                &c,
                EngineHooks::none(),
            );
            assert!(state.z_drift(&p) < 1e-8, "drift {}", state.z_drift(&p));
            out
        };
        let buffered = run(UpdatePath::Buffered);
        let blocked = run(UpdatePath::Blocked);
        assert_eq!(buffered.w, blocked.w, "blocked must replay buffered exactly");
        assert_eq!(buffered.objective, blocked.objective);
        assert_eq!(blocked.metrics.spill_iters, 0, "the slab fits the budget");
        let first = blocked.history.records.first().unwrap().objective;
        assert!(blocked.objective < first, "{first} -> {}", blocked.objective);
    }

    #[test]
    fn blocked_over_budget_spills_and_stays_consistent() {
        let p = make_problem(62, 48, 24, true);
        let sel = RandomSubset {
            rng: Pcg64::seeded(63),
            k: p.n_features(),
            size: 8,
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let mut c = cfg(4, 200);
        c.update_path = UpdatePath::Blocked;
        c.buffer_budget_mb = 0;
        let out = solve_from(
            &p,
            &state,
            Box::new(sel),
            accept::all(),
            &c,
            EngineHooks::none(),
        );
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert!(state.z_drift(&p) < 1e-8, "drift {}", state.z_drift(&p));
        assert_eq!(
            out.metrics.spill_iters, out.metrics.iterations,
            "every iteration should have spilled"
        );
    }

    #[test]
    fn kernel_tiers_agree_with_reference_engine() {
        // the engine-level discipline: every dispatched tier must land
        // within 1e-12 of the scalar-reference solve on the same stream
        use crate::kernel::{KernelChoice, KernelTier};
        let p = make_problem(64, 40, 16, false);
        let run = |fast: bool, choice: KernelChoice| {
            let sel = Cyclic {
                next: 0,
                k: p.n_features(),
            };
            let mut c = cfg(1, 800);
            c.fast_kernels = fast;
            c.kernel = choice;
            solve(&p, sel, AcceptAll, &c)
        };
        let reference = run(false, KernelChoice::Auto);
        assert_eq!(reference.metrics.kernel_tier, "reference");
        for choice in [KernelChoice::Scalar, KernelChoice::Avx2, KernelChoice::Avx512] {
            let fast = run(true, choice);
            // the requested tier is clamped to what the host supports,
            // so the report is the *resolved* tier
            let want_at_most = match choice {
                KernelChoice::Scalar => KernelTier::Scalar,
                KernelChoice::Avx2 => KernelTier::Avx2,
                _ => KernelTier::Avx512,
            };
            assert!(
                crate::kernel::dispatch(choice) <= want_at_most,
                "{choice:?} resolved above its ceiling"
            );
            assert_eq!(fast.metrics.kernel_tier, crate::kernel::dispatch(choice).name());
            assert!(
                (reference.objective - fast.objective).abs() < 1e-9,
                "{choice:?}: {} vs {}",
                reference.objective,
                fast.objective
            );
            for (a, b) in reference.w.iter().zip(&fast.w) {
                assert!((a - b).abs() < 1e-7, "{choice:?}: {a} vs {b}");
            }
        }
    }
}
