//! The parallel GenCD iteration engine — the OpenMP `parallel for`
//! analogue (Sec. 4.2 Implementation).
//!
//! A pool of `threads` workers (the calling thread is worker 0, the
//! *leader*) runs the four-step iteration in lock-step, separated by
//! barriers (OpenMP's implicit region barriers):
//!
//! ```text
//!   leader: Select J, decide gradient path, check stop   |  workers wait
//!   ── barrier ──
//!   all: refresh dloss chunk (when precomputation wins)
//!   ── barrier ──
//!   all: Propose over static chunk of J  (Algorithm 4)
//!   ── barrier ──
//!   leader: Accept -> J'                  (policy-dependent reduction)
//!   ── barrier ──
//!   all: Update over static chunk of J'   (Algorithm 3, atomic z)
//!   ── barrier ──
//!   leader: metrics, objective log, convergence checks
//! ```
//!
//! Work is divided with *static contiguous chunking* (the paper's
//! `schedule(static)`): thread t of T owns `len*t/T .. len*(t+1)/T`.
//! Shared numeric state is atomic (see [`super::problem::SharedState`]);
//! each phase gives every element a unique writer, and barriers provide
//! the happens-before edges, so relaxed ordering suffices throughout.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Barrier, Mutex, RwLock};

use super::accept::{resolve_global, Acceptor, ThreadBest};
use super::convergence::{History, Record, StopReason};
use super::linesearch;
use super::metrics::{Metrics, MetricsSnapshot};
use super::problem::{Problem, SharedState};
use super::propose::{self, Proposal};
use super::select::Selector;
use crate::loss;
use crate::util::Timer;

/// Engine knobs (a subset of [`crate::config::SolverConfig`], resolved).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub threads: usize,
    pub acceptor: Acceptor,
    /// Sec. 4.1 refinement steps on accepted proposals.
    pub line_search_steps: usize,
    pub max_iters: usize,
    pub max_seconds: f64,
    /// Relative-improvement stop (0 disables). Applied over logged
    /// objectives, three consecutive hits required.
    pub tol: f64,
    /// Log cadence in iterations; 0 = time-based (every ~50 ms).
    pub log_every: usize,
    /// Force the gradient path: `Some(true)` = always precompute dloss,
    /// `Some(false)` = always on-the-fly, `None` = per-iteration
    /// heuristic (ablation: `benches/ablations.rs`).
    pub force_dloss: Option<bool>,
    /// Update `z` with plain load+store instead of the CAS fetch-add.
    /// Safe when every `z[i]` has a unique writer per Update phase:
    /// single-threaded runs, or COLORING's conflict-free color classes
    /// (paper Sec. 4.2: "no need for synchronization in the Update step
    /// of the COLORING algorithm"). ~9x faster per nonzero (§Perf).
    pub conflict_free_update: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            threads: 1,
            acceptor: Acceptor::All,
            line_search_steps: 0,
            max_iters: usize::MAX,
            max_seconds: 10.0,
            tol: 0.0,
            log_every: 0,
            force_dloss: None,
            conflict_free_update: false,
        }
    }
}

/// Pluggable Propose backend for a whole selected block — how the
/// PJRT/HLO path (DESIGN.md §2) slots into the engine. Runs on the
/// leader, which is the *calling* thread (never a spawned one), so
/// implementations need not be `Send`; workers are parked at a barrier
/// during the call, giving it effectively exclusive access to the
/// shared arrays.
pub trait BlockProposer {
    /// Compute proposals for every `j` in `selected`, storing
    /// `delta[j]` / `phi[j]` into `state`.
    fn propose_block(
        &mut self,
        problem: &Problem,
        state: &SharedState,
        selected: &[u32],
    ) -> anyhow::Result<()>;

    fn name(&self) -> &str;
}

/// Outcome of a solve.
pub struct SolveOutput {
    pub w: Vec<f64>,
    pub objective: f64,
    pub nnz: usize,
    pub history: History,
    pub metrics: MetricsSnapshot,
    pub stop: StopReason,
    pub elapsed_secs: f64,
}

/// Iteration plan: written by the leader, read by workers. The RwLock is
/// uncontended outside phase edges (reads happen strictly after the
/// barrier following the leader's write).
struct Plan {
    selected: Vec<u32>,
    accepted: Vec<u32>,
    use_dloss: bool,
    /// Propose runs on the leader via the block proposer (HLO backend);
    /// workers skip the sparse propose loop.
    hlo: bool,
    stop: Option<StopReason>,
}

/// Static contiguous chunk of `0..len` owned by thread `tid` of `t`.
#[inline]
pub fn chunk(len: usize, tid: usize, threads: usize) -> std::ops::Range<usize> {
    let lo = len * tid / threads;
    let hi = len * (tid + 1) / threads;
    lo..hi
}

/// Barrier that compiles to nothing for single-thread runs (§Perf: a
/// 1-party `std::sync::Barrier` still takes a mutex; CCD/SCD and the
/// Fig. 2 T=1 anchors run millions of tiny iterations).
enum PhaseBarrier {
    Noop,
    Real(Barrier),
}

impl PhaseBarrier {
    fn new(threads: usize) -> Self {
        if threads <= 1 {
            PhaseBarrier::Noop
        } else {
            PhaseBarrier::Real(Barrier::new(threads))
        }
    }

    #[inline]
    fn wait(&self) {
        if let PhaseBarrier::Real(b) = self {
            b.wait();
        }
    }
}

/// Run GenCD from the zero vector.
pub fn solve(problem: &Problem, selector: Selector, cfg: &EngineConfig) -> SolveOutput {
    let state = SharedState::new(problem.n_samples(), problem.n_features());
    solve_from(problem, &state, selector, cfg, None)
}

/// Run GenCD from existing state (warm start), optionally with a custom
/// block-propose backend.
pub fn solve_from(
    problem: &Problem,
    state: &SharedState,
    selector: Selector,
    cfg: &EngineConfig,
    block_proposer: Option<&mut dyn BlockProposer>,
) -> SolveOutput {
    let threads = cfg.threads.max(1);
    let n = problem.n_samples();
    let mean_col_nnz = problem.x.mean_col_nnz();
    let unsync_update = cfg.conflict_free_update || threads == 1;
    // per-thread best reductions are only consumed by the greedy accept
    // policies; skip the bookkeeping for All / TopK (§Perf)
    let need_best = matches!(
        cfg.acceptor,
        Acceptor::ThreadGreedy | Acceptor::GlobalBest
    );

    let plan = RwLock::new(Plan {
        selected: Vec::new(),
        accepted: Vec::new(),
        use_dloss: false,
        hlo: false,
        stop: None,
    });
    let barrier = PhaseBarrier::new(threads);
    let metrics = Metrics::default();
    let bests: Vec<Mutex<ThreadBest>> =
        (0..threads).map(|_| Mutex::new(ThreadBest::NONE)).collect();
    // Leader-only bookkeeping, moved into the leader closure.
    let mut leader_state = LeaderState {
        selector,
        history: History::default(),
        timer: Timer::start(),
        last_log_at: -1.0,
        tol_hits: 0,
        iter: 0,
        block_proposer,
    };

    let run_worker = |tid: usize, leader: Option<&mut LeaderState>| {
        let mut leader = leader;
        // leader-only chained phase timestamps: one clock read per phase
        // boundary instead of start/stop pairs (§Perf — iterations can
        // be sub-microsecond)
        let mut mark = std::time::Instant::now();
        macro_rules! lap {
            ($counter:ident) => {
                if tid == 0 {
                    let now = std::time::Instant::now();
                    metrics
                        .$counter
                        .fetch_add((now - mark).as_nanos() as u64, Relaxed);
                    mark = now;
                }
            };
        }
        loop {
            // ---- leader: plan the iteration -------------------------
            if let Some(ls) = leader.as_deref_mut() {
                let mut p = plan.write().unwrap();
                plan_iteration(problem, state, cfg, ls, &metrics, &mut p, mean_col_nnz);
            }
            barrier.wait();
            lap!(select_nanos);

            let (stop, use_dloss, hlo_mode, selected_len) = {
                let p = plan.read().unwrap();
                (p.stop, p.use_dloss, p.hlo, p.selected.len())
            };
            if stop.is_some() {
                break;
            }

            // ---- dloss refresh (parallel over samples) ---------------
            if use_dloss {
                let r = chunk(n, tid, threads);
                propose::refresh_dloss(problem, state, r.start, r.end);
            }
            barrier.wait();

            // ---- Propose (parallel over J) ---------------------------
            {
                let p = plan.read().unwrap();
                if let Some(ls) = leader.as_deref_mut() {
                    if let Some(bp) = ls.block_proposer.as_deref_mut() {
                        bp.propose_block(problem, state, &p.selected)
                            .expect("block proposer failed");
                    }
                }
                if !hlo_mode {
                    let my = chunk(p.selected.len(), tid, threads);
                    let mut best = ThreadBest::NONE;
                    let mut nnz_work = 0u64;
                    for &j in &p.selected[my] {
                        let pr = propose::propose(problem, state, j as usize, use_dloss);
                        store_proposal(state, &pr);
                        nnz_work += problem.x.col_nnz(j as usize) as u64;
                        if need_best {
                            best.consider(j, pr.phi, pr.delta);
                        }
                    }
                    metrics.add_propose_nnz(nnz_work);
                    if need_best {
                        *bests[tid].lock().unwrap() = best;
                    }
                }
            }
            barrier.wait();
            lap!(propose_nanos);

            // ---- Accept (leader) -------------------------------------
            // All-policy fast path: J' == J; the Update phase reads
            // `selected` directly (plan.accept_is_select), so the write
            // lock and the copy are skipped entirely (§Perf)
            if leader.is_some() && cfg.acceptor != Acceptor::All {
                let mut p = plan.write().unwrap();
                if hlo_mode {
                    // derive per-chunk bests from the phi array so the
                    // accept policies behave identically to sparse mode
                    for t in 0..threads {
                        let my = chunk(p.selected.len(), t, threads);
                        let mut best = ThreadBest::NONE;
                        for &j in &p.selected[my] {
                            best.consider(
                                j,
                                state.phi[j as usize].load(Relaxed),
                                state.delta[j as usize].load(Relaxed),
                            );
                        }
                        *bests[t].lock().unwrap() = best;
                    }
                }
                let bests_snapshot: Vec<ThreadBest> =
                    bests.iter().map(|b| *b.lock().unwrap()).collect();
                let Plan {
                    selected, accepted, ..
                } = &mut *p;
                resolve_global(
                    cfg.acceptor,
                    &bests_snapshot,
                    selected,
                    |j| state.phi[j as usize].load(Relaxed),
                    accepted,
                );
            }
            if tid == 0 {
                metrics.add_proposals(selected_len as u64);
            }
            barrier.wait();
            lap!(accept_nanos);

            // ---- Update (parallel over J') ---------------------------
            {
                let p = plan.read().unwrap();
                let accepted: &[u32] = if cfg.acceptor == Acceptor::All {
                    &p.selected
                } else {
                    &p.accepted
                };
                let my = chunk(accepted.len(), tid, threads);
                let mut applied = 0u64;
                for &j in &accepted[my] {
                    let j = j as usize;
                    let d0 = state.delta[j].load(Relaxed);
                    if d0 == 0.0 && cfg.line_search_steps == 0 {
                        continue;
                    }
                    let d = linesearch::refine(problem, state, j, d0, cfg.line_search_steps);
                    if d == 0.0 {
                        continue;
                    }
                    // unique writer for w[j] within this phase
                    let wj = state.w[j].load(Relaxed);
                    state.w[j].store(wj + d, Relaxed);
                    let (rows, vals) = problem.x.col(j);
                    if unsync_update {
                        // unique writer per z[i] too (T=1 or coloring):
                        // plain load+store, no CAS (§Perf)
                        for (&i, &v) in rows.iter().zip(vals) {
                            let zi = &state.z[i as usize];
                            zi.store(zi.load(Relaxed) + d * v, Relaxed);
                        }
                    } else {
                        // z updates may collide across columns -> atomic add
                        for (&i, &v) in rows.iter().zip(vals) {
                            state.z[i as usize].fetch_add(d * v, Relaxed);
                        }
                    }
                    applied += 1;
                }
                metrics.add_updates(applied);
            }
            barrier.wait();
            lap!(update_nanos);
            // loop; leader re-plans at the top
        }
    };

    if threads == 1 {
        run_worker(0, Some(&mut leader_state));
    } else {
        std::thread::scope(|scope| {
            let run_worker = &run_worker;
            for tid in 1..threads {
                scope.spawn(move || run_worker(tid, None));
            }
            run_worker(0, Some(&mut leader_state));
        });
    }

    let elapsed = leader_state.timer.elapsed_secs();
    let w = state.w_snapshot();
    let z = state.z_snapshot();
    let objective = problem.objective(&w, &z);
    let stop = plan.read().unwrap().stop.unwrap_or(StopReason::MaxIters);
    SolveOutput {
        nnz: loss::nnz(&w),
        w,
        objective,
        history: leader_state.history,
        metrics: metrics.snapshot(),
        stop,
        elapsed_secs: elapsed,
    }
}

struct LeaderState<'a> {
    selector: Selector,
    history: History,
    timer: Timer,
    last_log_at: f64,
    tol_hits: u32,
    iter: usize,
    block_proposer: Option<&'a mut dyn BlockProposer>,
}

fn plan_iteration(
    problem: &Problem,
    state: &SharedState,
    cfg: &EngineConfig,
    ls: &mut LeaderState,
    metrics: &Metrics,
    plan: &mut Plan,
    mean_col_nnz: f64,
) {
    let elapsed = ls.timer.elapsed_secs();

    // ---- logging + divergence/tolerance checks ---------------------
    let should_log = match cfg.log_every {
        0 => elapsed - ls.last_log_at >= 0.05 || ls.iter == 0,
        every => ls.iter % every == 0,
    };
    if should_log {
        let t0 = Timer::start();
        let w = state.w_snapshot();
        let z = state.z_snapshot();
        let objective = problem.objective(&w, &z);
        ls.history.push(Record {
            elapsed_secs: elapsed,
            iter: ls.iter,
            updates: metrics.updates.load(Relaxed),
            objective,
            nnz: loss::nnz(&w),
        });
        ls.last_log_at = elapsed;
        if !objective.is_finite() || objective > 1e12 {
            plan.stop = Some(StopReason::Diverged);
        }
        if cfg.tol > 0.0 {
            let imp = ls.history.last_rel_improvement();
            if imp.abs() < cfg.tol {
                ls.tol_hits += 1;
            } else {
                ls.tol_hits = 0;
            }
            if ls.tol_hits >= 3 {
                plan.stop = Some(StopReason::Tolerance);
            }
        }
        metrics
            .log_nanos
            .fetch_add((t0.elapsed_secs() * 1e9) as u64, Relaxed);
    }

    // ---- stop checks ------------------------------------------------
    if plan.stop.is_none() {
        if ls.iter >= cfg.max_iters {
            plan.stop = Some(StopReason::MaxIters);
        } else if elapsed >= cfg.max_seconds {
            plan.stop = Some(StopReason::MaxSeconds);
        }
    }
    if plan.stop.is_some() {
        return;
    }

    // ---- Select ------------------------------------------------------
    ls.selector.select(&mut plan.selected);
    plan.hlo = ls.block_proposer.is_some();

    // ---- gradient-path heuristic --------------------------------------
    // Precomputing dloss costs n `ell'` evaluations; on-the-fly costs one
    // per traversed nonzero (~|J| * mean_col_nnz). Pick the cheaper.
    plan.use_dloss = match cfg.force_dloss {
        Some(forced) => forced,
        None => {
            ls.block_proposer.is_none()
                && plan.selected.len() as f64 * mean_col_nnz
                    >= problem.n_samples() as f64
        }
    };

    metrics.iterations.fetch_add(1, Relaxed);
    ls.iter += 1;
}

#[inline]
fn store_proposal(state: &SharedState, pr: &Proposal) {
    state.delta[pr.j].store(pr.delta, Relaxed);
    state.phi[pr.j].store(pr.phi, Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::{Logistic, Squared};
    use crate::sparse::io::Dataset;
    use crate::sparse::CooBuilder;
    use crate::util::Pcg64;

    /// Small random problem with a known planted signal.
    fn make_problem(seed: u64, n: usize, k: usize, logistic: bool) -> Problem {
        let mut rng = Pcg64::seeded(seed);
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < 0.3 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let mut x = b.build();
        x.normalize_columns();
        let wstar: Vec<f64> = (0..k)
            .map(|j| if j < 3 { 1.5 } else { 0.0 })
            .collect();
        let scores = x.matvec(&wstar);
        let y: Vec<f64> = if logistic {
            scores.iter().map(|&s| if s > 0.0 { 1.0 } else { -1.0 }).collect()
        } else {
            scores
        };
        let loss: Box<dyn crate::loss::Loss> =
            if logistic { Box::new(Logistic) } else { Box::new(Squared) };
        Problem::new(
            Dataset {
                x,
                y,
                name: "t".into(),
            },
            loss,
            1e-3,
        )
    }

    fn cfg(threads: usize, acceptor: Acceptor, iters: usize) -> EngineConfig {
        EngineConfig {
            threads,
            acceptor,
            max_iters: iters,
            max_seconds: 30.0,
            ..Default::default()
        }
    }

    #[test]
    fn ccd_descends_squared() {
        let p = make_problem(1, 24, 10, false);
        let sel = Selector::Cyclic {
            next: 0,
            k: p.n_features(),
        };
        let out = solve(&p, sel, &cfg(1, Acceptor::All, 200));
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first * 0.5, "{} -> {}", first, out.objective);
        assert_eq!(out.stop, StopReason::MaxIters);
        assert_eq!(out.metrics.iterations, 200);
    }

    #[test]
    fn shotgun_multithreaded_descends_logistic() {
        let p = make_problem(2, 32, 16, true);
        let sel = Selector::RandomSubset {
            rng: Pcg64::seeded(3),
            k: p.n_features(),
            size: 4,
        };
        let out = solve(&p, sel, &cfg(4, Acceptor::All, 300));
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{} -> {}", first, out.objective);
        // z must remain consistent with w after all the atomic updates
        let state = SharedState::from_warm_start(&p, &out.w);
        let z = state.z_snapshot();
        let obj = p.objective(&out.w, &z);
        assert!((obj - out.objective).abs() < 1e-6);
    }

    #[test]
    fn thread_greedy_accepts_at_most_one_per_thread() {
        let p = make_problem(4, 24, 12, true);
        let threads = 3;
        let sel = Selector::RandomSubset {
            rng: Pcg64::seeded(5),
            k: p.n_features(),
            size: 9,
        };
        let out = solve(&p, sel, &cfg(threads, Acceptor::ThreadGreedy, 50));
        assert!(out.metrics.updates <= 50 * threads as u64);
        assert!(out.metrics.accept_rate() <= threads as f64 / 9.0 + 1e-9);
    }

    #[test]
    fn greedy_single_update_per_iteration() {
        let p = make_problem(6, 20, 8, false);
        let sel = Selector::All { k: p.n_features() };
        let out = solve(&p, sel, &cfg(2, Acceptor::GlobalBest, 40));
        assert!(out.metrics.updates <= 40);
        assert!(out.objective <= out.history.records[0].objective);
    }

    #[test]
    fn topk_bounded() {
        let p = make_problem(7, 20, 12, true);
        let sel = Selector::All { k: p.n_features() };
        let out = solve(&p, sel, &cfg(2, Acceptor::GlobalTopK(3), 30));
        assert!(out.metrics.updates <= 90);
    }

    #[test]
    fn deterministic_single_thread() {
        let p = make_problem(8, 16, 8, true);
        let mk = || {
            let sel = Selector::RandomSubset {
                rng: Pcg64::seeded(9),
                k: p.n_features(),
                size: 3,
            };
            solve(&p, sel, &cfg(1, Acceptor::All, 100))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.w, b.w);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn dloss_paths_equivalent() {
        let p = make_problem(10, 20, 10, true);
        let run = |force: Option<bool>| {
            let sel = Selector::Cyclic {
                next: 0,
                k: p.n_features(),
            };
            let mut c = cfg(1, Acceptor::All, 60);
            c.force_dloss = force;
            solve(&p, sel, &c)
        };
        let a = run(Some(true));
        let b = run(Some(false));
        for (x, y) in a.w.iter().zip(&b.w) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn max_seconds_stops() {
        let p = make_problem(11, 16, 8, true);
        let sel = Selector::All { k: p.n_features() };
        let mut c = cfg(2, Acceptor::GlobalBest, usize::MAX);
        c.max_seconds = 0.2;
        let out = solve(&p, sel, &c);
        assert_eq!(out.stop, StopReason::MaxSeconds);
        assert!(out.elapsed_secs < 5.0);
    }

    #[test]
    fn tolerance_stops() {
        let p = make_problem(12, 16, 8, false);
        let sel = Selector::Cyclic {
            next: 0,
            k: p.n_features(),
        };
        let mut c = cfg(1, Acceptor::All, usize::MAX);
        c.max_seconds = 20.0;
        c.tol = 1e-10;
        c.log_every = 10;
        let out = solve(&p, sel, &c);
        assert_eq!(out.stop, StopReason::Tolerance);
    }

    #[test]
    fn line_search_accelerates_convergence() {
        let p = make_problem(13, 30, 10, true);
        let run = |steps: usize| {
            let sel = Selector::Cyclic {
                next: 0,
                k: p.n_features(),
            };
            let mut c = cfg(1, Acceptor::All, 50);
            c.line_search_steps = steps;
            solve(&p, sel, &c)
        };
        let plain = run(0);
        let refined = run(20);
        assert!(
            refined.objective <= plain.objective + 1e-12,
            "{} vs {}",
            refined.objective,
            plain.objective
        );
    }

    #[test]
    fn z_consistency_under_concurrency() {
        // many threads, many iterations: incremental z must not drift
        let p = make_problem(14, 40, 24, true);
        let sel = Selector::RandomSubset {
            rng: Pcg64::seeded(15),
            k: p.n_features(),
            size: 8,
        };
        let state = SharedState::new(p.n_samples(), p.n_features());
        let c = cfg(8, Acceptor::All, 200);
        solve_from(&p, &state, sel, &c, None);
        assert!(state.z_drift(&p) < 1e-8, "drift {}", state.z_drift(&p));
    }
}
