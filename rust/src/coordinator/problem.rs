//! Problem definition + the shared solver state of Table 1.

use crate::loss::{self, Loss};
use crate::sparse::io::Dataset;
use crate::sparse::CscMatrix;
use crate::util::atomic::SyncF64Vec;

/// An l1-regularized ERM instance (Eq. 1): design matrix, labels, loss,
/// regularization strength, plus cached per-column curvature info.
pub struct Problem {
    pub x: CscMatrix,
    pub y: Vec<f64>,
    pub loss: Box<dyn Loss>,
    pub lam: f64,
    /// Squared column norms; the per-coordinate curvature bound is
    /// `beta * col_sq_norm[j]` (== `beta` for normalized columns, the
    /// paper's setting).
    pub col_sq_norms: Vec<f64>,
}

impl Problem {
    pub fn new(ds: Dataset, loss: Box<dyn Loss>, lam: f64) -> Self {
        let col_sq_norms = ds.x.col_sq_norms();
        Self {
            x: ds.x,
            y: ds.y,
            loss,
            lam,
            col_sq_norms,
        }
    }

    #[inline]
    pub fn n_samples(&self) -> usize {
        self.x.n_rows()
    }

    #[inline]
    pub fn n_features(&self) -> usize {
        self.x.n_cols()
    }

    /// Per-coordinate quadratic upper-bound curvature (Sec. 3.2
    /// specialized to coordinate j). With `F(w) = (1/n) sum_i ell(...)`,
    /// `d^2F/ddelta^2 = (1/n) sum_i ell'' x_ij^2 <= beta ||X_j||^2 / n`.
    /// For squared loss this equals `H_jj` exactly, so the Eq. (7) step
    /// is the exact coordinate minimizer (Sec. 3.1).
    #[inline]
    pub fn beta_j(&self, j: usize) -> f64 {
        (self.loss.beta() * self.col_sq_norms[j] / self.n_samples() as f64).max(1e-12)
    }

    /// Full objective (Eq. 1) at explicit (w, z).
    pub fn objective(&self, w: &[f64], z: &[f64]) -> f64 {
        loss::objective(self.loss.as_ref(), &self.y, z, w, self.lam)
    }
}

/// The shared arrays of Table 1 (plus the cached loss-derivative vector).
///
/// Storage is [`SyncF64Vec`]: every array supports both plain and atomic
/// element access to the same memory. The engine's phase protocol gives
/// each element a unique writer within a phase and a barrier-provided
/// happens-before edge between phases (see [`crate::util::par`]), so the
/// hot paths use plain accesses — Propose reads `w`/`dloss`/`z` and
/// writes `delta`/`phi` without a single atomic-typed instruction — and
/// only the colliding `z` scatter of the Update phase's atomic mode goes
/// through `state.z[i].fetch_add(..)` (Algorithm 3's `omp atomic`).
pub struct SharedState {
    /// Weight estimate `w` (k).
    pub w: SyncF64Vec,
    /// Fitted values `z = X w` (n) — updated incrementally (Algorithm 3;
    /// atomic, buffered, or conflict-free depending on the engine's
    /// update path).
    pub z: SyncF64Vec,
    /// Proposed increments `delta` (k).
    pub delta: SyncF64Vec,
    /// Proposal proxies `phi` (k), Eq. 9 — more negative is better.
    pub phi: SyncF64Vec,
    /// Cached `ell'(y_i, z_i)` (n), recomputed each iteration when the
    /// engine decides precomputation is cheaper (see `engine`).
    pub dloss: SyncF64Vec,
}

impl SharedState {
    pub fn new(n: usize, k: usize) -> Self {
        Self {
            w: SyncF64Vec::zeros(k),
            z: SyncF64Vec::zeros(n),
            delta: SyncF64Vec::zeros(k),
            phi: SyncF64Vec::zeros(k),
            dloss: SyncF64Vec::zeros(n),
        }
    }

    /// Initialize from a warm-start weight vector.
    pub fn from_warm_start(problem: &Problem, w0: &[f64]) -> Self {
        let state = Self::new(problem.n_samples(), problem.n_features());
        state.apply_warm_start(problem, w0);
        state
    }

    /// Load a warm-start weight vector into existing state
    /// (`w = w0`, `z = X w0`).
    pub fn apply_warm_start(&self, problem: &Problem, w0: &[f64]) {
        self.w.copy_from(w0);
        self.z.copy_from(&problem.x.matvec(w0));
    }

    pub fn w_snapshot(&self) -> Vec<f64> {
        self.w.snapshot()
    }

    pub fn z_snapshot(&self) -> Vec<f64> {
        self.z.snapshot()
    }

    /// Recompute `z = X w` exactly (drift repair / invariant tests).
    pub fn recompute_z(&self, problem: &Problem) -> Vec<f64> {
        problem.x.matvec(&self.w_snapshot())
    }

    /// Max |z - X w| drift from incremental updates (diagnostics).
    pub fn z_drift(&self, problem: &Problem) -> f64 {
        let exact = self.recompute_z(problem);
        let cur = self.z_snapshot();
        exact
            .iter()
            .zip(&cur)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::{Logistic, Squared};
    use crate::sparse::csc::small_fixture;

    fn fixture_problem() -> Problem {
        let ds = Dataset {
            x: small_fixture(),
            y: vec![1.0, -1.0, 1.0, -1.0],
            name: "t".into(),
        };
        Problem::new(ds, Box::new(Squared), 0.1)
    }

    #[test]
    fn beta_j_scales_with_column_norm() {
        let p = fixture_problem();
        assert_eq!(p.beta_j(0), 17.0 / 4.0);
        assert_eq!(p.beta_j(2), 40.0 / 4.0);
    }

    #[test]
    fn warm_start_consistent() {
        let p = fixture_problem();
        let w0 = vec![0.5, -0.25, 1.0];
        let s = SharedState::from_warm_start(&p, &w0);
        assert_eq!(s.w_snapshot(), w0);
        assert!(s.z_drift(&p) < 1e-12);
    }

    #[test]
    fn objective_matches_loss_module() {
        let ds = Dataset {
            x: small_fixture(),
            y: vec![1.0, -1.0, 1.0, -1.0],
            name: "t".into(),
        };
        let p = Problem::new(ds, Box::new(Logistic), 0.05);
        let w = vec![0.1, 0.0, -0.2];
        let z = p.x.matvec(&w);
        let want = crate::loss::objective(&Logistic, &p.y, &z, &w, 0.05);
        assert!((p.objective(&w, &z) - want).abs() < 1e-15);
    }

    #[test]
    fn zero_state() {
        let p = fixture_problem();
        let s = SharedState::new(p.n_samples(), p.n_features());
        assert_eq!(s.w_snapshot(), vec![0.0; 3]);
        assert!(s.z_drift(&p) < 1e-15);
    }
}
