//! Solver counters: updates, proposals, iterations, per-phase time — the
//! measurements behind Figure 2 (updates/sec) and the §Perf profiles.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Shared counters. The engine's workers accumulate work counts in
/// cache-padded per-thread slots (no shared-line traffic on the hot
/// path); the leader folds them in here during the Select phase, so
/// `updates`/`propose_nnz` are leader-written totals. The remaining
/// fields are leader-only throughout.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Coordinate updates applied (|J'| summed over iterations).
    pub updates: AtomicU64,
    /// Proposals computed (|J| summed over iterations).
    pub proposals: AtomicU64,
    /// Iterations completed.
    pub iterations: AtomicU64,
    /// Nonzeros traversed in Propose (work metric).
    pub propose_nnz: AtomicU64,
    /// Iterations whose buffered update spilled to sparse per-thread
    /// maps because the dense accumulators exceeded the memory budget
    /// (`EngineConfig::buffer_budget_mb`).
    pub spill_iters: AtomicU64,
    /// Full-set KKT sweeps run by the screening layer (leader-stored).
    pub kkt_passes: AtomicU64,
    /// Coordinates a sweep returned to the active set because their
    /// violation turned positive while deactivated (leader-stored).
    pub reactivations: AtomicU64,
    /// Active-set size after the latest sweep (leader-stored; the
    /// engine replaces it with an exact popcount in the final
    /// snapshot).
    pub active_cols: AtomicU64,
    /// Nanoseconds spent in each phase (leader-measured).
    pub select_nanos: AtomicU64,
    pub propose_nanos: AtomicU64,
    pub accept_nanos: AtomicU64,
    pub update_nanos: AtomicU64,
    /// Screen-phase time: the full-set KKT sweeps plus the dloss
    /// refresh that precedes them on sweep iterations.
    pub screen_nanos: AtomicU64,
    pub log_nanos: AtomicU64,
}

impl Metrics {
    /// Leader-only (the Accept phase).
    ///
    /// There are deliberately no `add_updates`/`add_propose_nnz`
    /// helpers: those totals are *stored* by the leader from the folded
    /// per-thread slots — mixing in `fetch_add` increments would corrupt
    /// them.
    pub fn add_proposals(&self, n: u64) {
        self.proposals.fetch_add(n, Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            updates: self.updates.load(Relaxed),
            proposals: self.proposals.load(Relaxed),
            iterations: self.iterations.load(Relaxed),
            propose_nnz: self.propose_nnz.load(Relaxed),
            spill_iters: self.spill_iters.load(Relaxed),
            kkt_passes: self.kkt_passes.load(Relaxed),
            reactivations: self.reactivations.load(Relaxed),
            active_cols: self.active_cols.load(Relaxed),
            select_secs: self.select_nanos.load(Relaxed) as f64 * 1e-9,
            propose_secs: self.propose_nanos.load(Relaxed) as f64 * 1e-9,
            accept_secs: self.accept_nanos.load(Relaxed) as f64 * 1e-9,
            update_secs: self.update_nanos.load(Relaxed) as f64 * 1e-9,
            screen_secs: self.screen_nanos.load(Relaxed) as f64 * 1e-9,
            log_secs: self.log_nanos.load(Relaxed) as f64 * 1e-9,
            auto_cas_ratio: 0.0,
            auto_switch_factor: 0.0,
            shards: 0,
            reconcile_secs: 0.0,
            replica_divergence: 0.0,
            numa_nodes: 0,
            dirty_chunk_frac: 0.0,
            reconcile_rounds_skipped: 0,
            sim_events: 0,
            staleness_forced_reconciles: 0,
            shard_failures: 0,
            wire_bytes_tx: 0,
            wire_bytes_rx: 0,
            codec_secs: 0.0,
            kernel_tier: "",
        }
    }
}

/// Plain-value copy of [`Metrics`] for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub updates: u64,
    pub proposals: u64,
    pub iterations: u64,
    pub propose_nnz: u64,
    /// Buffered iterations that spilled to sparse maps (memory budget).
    pub spill_iters: u64,
    /// Full-set KKT sweeps run by the screening layer
    /// ([`crate::screen`]; 0 when screening is off).
    pub kkt_passes: u64,
    /// Coordinates sweeps returned to the active set after their KKT
    /// violation turned positive while deactivated (screening's
    /// repaired mistakes; 0 when screening is off).
    pub reactivations: u64,
    /// Active coordinates at the end of the solve — exact popcount of
    /// the screening bitmask (sum over shards when sharded; 0 when
    /// screening is off). Shrinking well below the feature count is the
    /// whole point of the screening layer.
    pub active_cols: u64,
    pub select_secs: f64,
    pub propose_secs: f64,
    pub accept_secs: f64,
    pub update_secs: f64,
    /// Screen-phase seconds: full-set KKT sweeps plus the dloss refresh
    /// preceding them on sweep iterations (0 when screening is off).
    pub screen_secs: f64,
    pub log_secs: f64,
    /// Measured CAS-vs-plain-store cost ratio behind the fitted `Auto`
    /// update-path switch (0 when the solve never calibrated: forced
    /// paths or single-threaded runs).
    pub auto_cas_ratio: f64,
    /// The fitted switch constant actually used: `Auto` flips to
    /// buffered when `|J'|·nnz̄ >= factor · n`. Calibrated runs derive
    /// it from `auto_cas_ratio` and the thread count; uncalibrated runs
    /// (forced paths, single-threaded) report the seed's neutral 1.0 —
    /// test `auto_cas_ratio == 0` to detect those.
    pub auto_switch_factor: f64,
    /// Shard count of the execution layer that produced this snapshot
    /// (0 for plain single-engine solves).
    pub shards: u64,
    /// Wall-clock seconds spent reconciling per-shard residual replicas
    /// at round boundaries (max across shard leaders; 0 unsharded).
    pub reconcile_secs: f64,
    /// Largest reconcile correction ever applied to a sample *the shard
    /// itself updated that round* — the magnitude of genuine
    /// cross-shard write conflicts. 0 when shards touch disjoint
    /// samples (a perfect min-overlap partition on block-structured
    /// data), and 0 for unsharded or single-shard solves.
    pub replica_divergence: f64,
    /// NUMA nodes the shard pools were pinned across
    /// (`ShardedConfig::numa_pin`): 0 when pinning was off (or the
    /// solve was unsharded), 1 when pinning was requested but degraded
    /// to a no-op (single-node host, non-Linux, or every
    /// `sched_setaffinity` refused — the warning value), >= 2 for a
    /// real multi-node spread.
    pub numa_nodes: u64,
    /// Mean fraction of z chunks the delta reconcile actually folded
    /// (dirty in some shard since the last reconcile), over all
    /// reconciles. 1.0 means every fold was dense anyway; small values
    /// are the sparse-reconcile win (screened runs touch a few percent
    /// of z per round). 0 for dense-reconcile, single-shard or
    /// unsharded solves.
    pub dirty_chunk_frac: f64,
    /// Rounds the adaptive reconcile cadence ran *without* a reconcile
    /// (`ShardedConfig::reconcile_max_rounds` > `reconcile_every`):
    /// each skipped round is a full barrier protocol + fold the shards
    /// did not pay. 0 at the default every-round cadence.
    pub reconcile_rounds_skipped: u64,
    /// Virtual-time events recorded by the fault-injection simulator
    /// ([`crate::sim`]) when the solve ran under a `SimLink`; 0 on every
    /// real (non-simulated) solve.
    pub sim_events: u64,
    /// Reconciles forced by the `max_staleness_rounds` bound clamping
    /// the adaptive cadence (the gap the doubling wanted exceeded the
    /// staleness budget). 0 when the knob is off or never bound.
    pub staleness_forced_reconciles: u64,
    /// Shard pools that died mid-solve (panic, barrier timeout, or
    /// poisoned peer). Nonzero exactly when the stop reason is
    /// [`ShardFailed`](super::convergence::StopReason::ShardFailed).
    pub shard_failures: u64,
    /// Bytes encoded and sent through a wire transport
    /// ([`crate::net`]): delta frames + decision frames, summed across
    /// shards. 0 on in-memory links (barrier, sim).
    pub wire_bytes_tx: u64,
    /// Bytes received and decoded from the wire (counts duplicate
    /// deliveries, so it can exceed `wire_bytes_tx` under injected
    /// faults). 0 on in-memory links.
    pub wire_bytes_rx: u64,
    /// Seconds spent in the wire codec — encoding and decoding frames,
    /// not blocking waits (max across shard leaders, the
    /// `reconcile_secs` convention). 0 on in-memory links.
    pub codec_secs: f64,
    /// Kernel mode the solve resolved once at startup
    /// ([`crate::kernel::KernelMode::name`]): `"reference"` for the
    /// bit-exact scalar seed, else the dispatched SIMD tier
    /// (`"scalar"`/`"avx2"`/`"avx512"`). Empty for snapshots that never
    /// ran the engine (e.g. [`Default`]).
    pub kernel_tier: &'static str,
}

impl MetricsSnapshot {
    /// Figure 2's y-axis.
    pub fn updates_per_sec(&self, elapsed: f64) -> f64 {
        self.updates as f64 / elapsed.max(1e-12)
    }

    /// Acceptance ratio |J'| / |J|.
    pub fn accept_rate(&self) -> f64 {
        if self.proposals == 0 {
            0.0
        } else {
            self.updates as f64 / self.proposals as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        // updates/propose_nnz are leader-stored totals (see struct docs)
        m.updates.store(3, Relaxed);
        m.updates.store(7, Relaxed);
        m.add_proposals(10);
        m.propose_nnz.store(100, Relaxed);
        m.iterations.store(2, Relaxed);
        let s = m.snapshot();
        assert_eq!(s.updates, 7);
        assert_eq!(s.proposals, 10);
        assert_eq!(s.iterations, 2);
        assert!((s.accept_rate() - 0.7).abs() < 1e-12);
        assert!((s.updates_per_sec(2.0) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn empty_rates() {
        let s = MetricsSnapshot::default();
        assert_eq!(s.accept_rate(), 0.0);
        assert_eq!(s.updates_per_sec(0.0), 0.0);
    }
}
