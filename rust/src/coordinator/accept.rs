//! Step three: Accept (Sec. 2.3) — which proposals survive.
//!
//! Acceptance is an *open* extension point: [`Accept`] is an object-safe
//! trait and the paper's policies are plain implementations of it.
//! [`AcceptAll`] (SHOTGUN, COLORING, CCD/SCD) bypasses the proxy
//! entirely; [`ThreadGreedy`] keeps each thread's best proposal (the
//! paper's novel algorithm — no cross-thread synchronization);
//! [`GlobalBest`] keeps the single best across threads (GREEDY,
//! synchronizing reduction); [`GlobalTopK`] is the §7 extension: the
//! best K *independently of which thread proposed them*. Implement the
//! trait yourself (through
//! [`SolverBuilder::accept`](crate::solver::SolverBuilder::accept)) to
//! plug in a new policy.

/// Everything an accept policy may inspect, assembled by the engine's
/// leader after the Propose phase.
pub struct AcceptContext<'a> {
    /// Each worker's best-proposal reduction (one slot per thread;
    /// meaningful only when the policy reports
    /// [`needs_thread_bests`](Accept::needs_thread_bests) — otherwise
    /// the slots are stale).
    pub bests: &'a [ThreadBest],
    /// This iteration's selected set J (duplicate-free).
    pub selected: &'a [u32],
    /// Proposal proxy phi_j (Eq. 9; more negative is better) for any
    /// selected j.
    pub phi_of: &'a dyn Fn(u32) -> f64,
    /// Worker count (for policies that budget per thread).
    pub threads: usize,
}

/// An accept policy: chooses the surviving subset J' ⊆ J.
///
/// # Contract
///
/// * `accept` runs on the leader thread while workers are parked at a
///   barrier, once per iteration. Policies may be stateful.
/// * The output must be duplicate-free and a subset of `ctx.selected` —
///   J' coordinates become the Update phase's unique writers; the
///   engine's debug build asserts duplicate-freedom.
/// * `accept_bound` must never under-estimate |J'| for a given |J|: the
///   engine sizes its buffered-update decision with it at plan time.
///   The default (|J| itself) is always safe.
pub trait Accept: Send {
    /// Fill `out` with the accepted set J'. The engine clears `out`
    /// before every call — implementations append only.
    fn accept(&mut self, ctx: AcceptContext<'_>, out: &mut Vec<u32>);

    /// Does this policy consume the per-thread best reductions? When
    /// `true`, each Propose worker tracks its running best (j, phi,
    /// delta) and publishes it to `ctx.bests`. Defaults to `true` so a
    /// custom policy never sees stale slots; built-ins that ignore
    /// `bests` override to `false` and skip the bookkeeping (§Perf).
    fn needs_thread_bests(&self) -> bool {
        true
    }

    /// `true` only for the accept-everything policy: the engine then
    /// skips the Accept phase entirely and hands the selection straight
    /// to Update (the J' == J fast path).
    fn passes_all(&self) -> bool {
        false
    }

    /// Upper bound on |J'| given |J| = `selected` — a *sizing hint* for
    /// the engine's plan-time update-path heuristic. Must not
    /// under-estimate; tightness only improves the heuristic.
    fn accept_bound(&self, selected: usize, _threads: usize) -> usize {
        selected
    }

    /// Human-readable policy name (logs and summaries).
    fn name(&self) -> String {
        "custom".into()
    }
}

impl<A: Accept + ?Sized> Accept for Box<A> {
    fn accept(&mut self, ctx: AcceptContext<'_>, out: &mut Vec<u32>) {
        (**self).accept(ctx, out)
    }
    fn needs_thread_bests(&self) -> bool {
        (**self).needs_thread_bests()
    }
    fn passes_all(&self) -> bool {
        (**self).passes_all()
    }
    fn accept_bound(&self, selected: usize, threads: usize) -> usize {
        (**self).accept_bound(selected, threads)
    }
    fn name(&self) -> String {
        (**self).name()
    }
}

/// A per-thread reduction result: the best proposal seen by one worker.
#[derive(Clone, Copy, Debug)]
pub struct ThreadBest {
    pub j: u32,
    pub phi: f64,
    pub delta: f64,
}

impl ThreadBest {
    pub const NONE: ThreadBest = ThreadBest {
        j: u32::MAX,
        phi: f64::INFINITY,
        delta: 0.0,
    };

    #[inline]
    pub fn consider(&mut self, j: u32, phi: f64, delta: f64) {
        // Strictly-better keeps the first-seen on ties => deterministic.
        if phi < self.phi {
            *self = ThreadBest { j, phi, delta };
        }
    }

    pub fn is_some(&self) -> bool {
        self.j != u32::MAX && self.delta != 0.0
    }
}

/// Accept every proposal (J' = J). The engine special-cases this via
/// [`Accept::passes_all`] and never materializes a separate accepted
/// list.
#[derive(Clone, Copy, Debug, Default)]
pub struct AcceptAll;

impl Accept for AcceptAll {
    fn accept(&mut self, ctx: AcceptContext<'_>, out: &mut Vec<u32>) {
        out.extend_from_slice(ctx.selected);
    }

    fn needs_thread_bests(&self) -> bool {
        false
    }

    fn passes_all(&self) -> bool {
        true
    }

    fn accept_bound(&self, selected: usize, _threads: usize) -> usize {
        selected
    }

    fn name(&self) -> String {
        "all".into()
    }
}

/// Each thread accepts the best (lowest phi) of its own chunk — the
/// paper's THREAD-GREEDY, zero cross-thread synchronization.
///
/// J' must be duplicate-free (unique-writer invariant of the engine's
/// Update phase); the selection is already deduplicated by the engine's
/// plan-time filter, but two threads can still report the same j only if
/// the selection repeated — collapsed here anyway (first occurrence
/// wins, allocation-free: the set is at most one entry per thread).
#[derive(Clone, Copy, Debug, Default)]
pub struct ThreadGreedy;

impl Accept for ThreadGreedy {
    fn accept(&mut self, ctx: AcceptContext<'_>, out: &mut Vec<u32>) {
        for b in ctx.bests {
            if b.is_some() && !out.contains(&b.j) {
                out.push(b.j);
            }
        }
    }

    fn accept_bound(&self, selected: usize, threads: usize) -> usize {
        threads.min(selected)
    }

    fn name(&self) -> String {
        "thread-greedy".into()
    }
}

/// Single globally-best proposal (classic GREEDY).
#[derive(Clone, Copy, Debug, Default)]
pub struct GlobalBest;

impl Accept for GlobalBest {
    fn accept(&mut self, ctx: AcceptContext<'_>, out: &mut Vec<u32>) {
        let mut best = ThreadBest::NONE;
        for b in ctx.bests {
            if b.is_some() {
                best.consider(b.j, b.phi, b.delta);
            }
        }
        if best.is_some() {
            out.push(best.j);
        }
    }

    fn accept_bound(&self, selected: usize, _threads: usize) -> usize {
        1.min(selected)
    }

    fn name(&self) -> String {
        "global-best".into()
    }
}

/// Best `k` proposals across all threads (§7 extension). Keeps only
/// strictly-improving (phi < 0) proposals, in deterministic j order.
#[derive(Clone, Copy, Debug)]
pub struct GlobalTopK {
    pub k: usize,
}

impl Accept for GlobalTopK {
    fn accept(&mut self, ctx: AcceptContext<'_>, out: &mut Vec<u32>) {
        // partial selection of the k most-negative phi values
        let mut scored: Vec<(f64, u32)> = ctx
            .selected
            .iter()
            .map(|&j| ((ctx.phi_of)(j), j))
            .collect();
        let k = self.k.min(scored.len());
        if k == 0 {
            return;
        }
        scored.select_nth_unstable_by(k - 1, |a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        let mut top: Vec<(f64, u32)> = scored[..k].to_vec();
        // deterministic order (by j) and drop no-op proposals
        top.sort_by_key(|&(_, j)| j);
        for (phi, j) in top {
            if phi < 0.0 {
                out.push(j);
            }
        }
    }

    fn needs_thread_bests(&self) -> bool {
        false
    }

    fn accept_bound(&self, selected: usize, _threads: usize) -> usize {
        self.k.min(selected)
    }

    fn name(&self) -> String {
        format!("top{}", self.k)
    }
}

/// Accept-everything policy, boxed.
pub fn all() -> Box<dyn Accept> {
    Box::new(AcceptAll)
}

/// Per-thread-best policy (THREAD-GREEDY), boxed.
pub fn thread_greedy() -> Box<dyn Accept> {
    Box::new(ThreadGreedy)
}

/// Single-global-best policy (GREEDY), boxed.
pub fn global_best() -> Box<dyn Accept> {
    Box::new(GlobalBest)
}

/// Global top-k policy (§7 extension), boxed.
pub fn top_k(k: usize) -> Box<dyn Accept> {
    Box::new(GlobalTopK { k })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bests() -> Vec<ThreadBest> {
        vec![
            ThreadBest {
                j: 3,
                phi: -0.5,
                delta: 0.1,
            },
            ThreadBest::NONE,
            ThreadBest {
                j: 7,
                phi: -0.9,
                delta: -0.2,
            },
        ]
    }

    fn resolve(
        policy: &mut dyn Accept,
        bests: &[ThreadBest],
        selected: &[u32],
        phi_of: impl Fn(u32) -> f64,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        policy.accept(
            AcceptContext {
                bests,
                selected,
                phi_of: &phi_of,
                threads: bests.len().max(1),
            },
            out,
        );
    }

    #[test]
    fn all_passes_selection_through() {
        let mut out = Vec::new();
        resolve(&mut AcceptAll, &bests(), &[1, 2, 3], |_| 0.0, &mut out);
        assert_eq!(out, vec![1, 2, 3]);
        assert!(AcceptAll.passes_all());
        assert!(!AcceptAll.needs_thread_bests());
    }

    #[test]
    fn thread_greedy_keeps_per_thread_bests() {
        let mut out = Vec::new();
        resolve(&mut ThreadGreedy, &bests(), &[], |_| 0.0, &mut out);
        assert_eq!(out, vec![3, 7]); // thread 1 had nothing
        assert!(ThreadGreedy.needs_thread_bests());
    }

    #[test]
    fn global_best_takes_minimum_phi() {
        let mut out = Vec::new();
        resolve(&mut GlobalBest, &bests(), &[], |_| 0.0, &mut out);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn topk_selects_most_negative() {
        let selected = [0u32, 1, 2, 3, 4];
        let phi = [-0.1, -0.9, 0.0, -0.5, -0.3];
        let mut out = Vec::new();
        resolve(
            &mut GlobalTopK { k: 3 },
            &[],
            &selected,
            |j| phi[j as usize],
            &mut out,
        );
        assert_eq!(out, vec![1, 3, 4]); // sorted by j, phi<0 only
    }

    #[test]
    fn topk_drops_nonnegative_phi() {
        let selected = [0u32, 1];
        let phi = [0.0, 0.0];
        let mut out = Vec::new();
        resolve(
            &mut GlobalTopK { k: 2 },
            &[],
            &selected,
            |j| phi[j as usize],
            &mut out,
        );
        assert!(out.is_empty());
    }

    #[test]
    fn bounds_are_upper_bounds_and_names_stable() {
        assert_eq!(AcceptAll.accept_bound(10, 4), 10);
        assert_eq!(ThreadGreedy.accept_bound(10, 4), 4);
        assert_eq!(ThreadGreedy.accept_bound(2, 4), 2);
        assert_eq!(GlobalBest.accept_bound(10, 4), 1);
        assert_eq!(GlobalTopK { k: 3 }.accept_bound(10, 4), 3);
        assert_eq!(AcceptAll.name(), "all");
        assert_eq!(ThreadGreedy.name(), "thread-greedy");
        assert_eq!(GlobalBest.name(), "global-best");
        assert_eq!(top_k(5).name(), "top5");
    }

    #[test]
    fn prop_accepted_subset_of_selected() {
        // the framework invariant of Sec. 2.3: J' ⊆ J for every policy
        use crate::util::prop;
        prop::check("J' subset of J", 100, |rng, size| {
            let k = 2 + rng.below(2 * size.max(2));
            let sel_n = 1 + rng.below(k);
            let selected: Vec<u32> = rng
                .sample_distinct(k, sel_n)
                .into_iter()
                .map(|j| j as u32)
                .collect();
            let phi: Vec<f64> = (0..k).map(|_| rng.range_f64(-1.0, 0.0)).collect();
            let threads = 1 + rng.below(6);
            // per-thread bests drawn from the selection chunks
            let bests: Vec<ThreadBest> = (0..threads)
                .map(|t| {
                    let lo = selected.len() * t / threads;
                    let hi = selected.len() * (t + 1) / threads;
                    let mut b = ThreadBest::NONE;
                    for &j in &selected[lo..hi] {
                        b.consider(j, phi[j as usize], 0.1);
                    }
                    b
                })
                .collect();
            let mut policies: Vec<Box<dyn Accept>> = vec![
                all(),
                thread_greedy(),
                global_best(),
                top_k(1 + rng.below(sel_n)),
            ];
            let sel_set: std::collections::HashSet<u32> =
                selected.iter().copied().collect();
            let mut out = Vec::new();
            for policy in &mut policies {
                let name = policy.name();
                out.clear();
                policy.accept(
                    AcceptContext {
                        bests: &bests,
                        selected: &selected,
                        phi_of: &|j| phi[j as usize],
                        threads,
                    },
                    &mut out,
                );
                for &j in &out {
                    if !sel_set.contains(&j) {
                        return Err(format!("{name}: {j} not selected"));
                    }
                }
                // no duplicates in J', and the plan-time bound holds
                let uniq: std::collections::HashSet<u32> = out.iter().copied().collect();
                if uniq.len() != out.len() {
                    return Err(format!("{name}: duplicate accepts {out:?}"));
                }
                if out.len() > policy.accept_bound(selected.len(), threads) {
                    return Err(format!(
                        "{name}: |J'|={} exceeds accept_bound {}",
                        out.len(),
                        policy.accept_bound(selected.len(), threads)
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn duplicate_thread_bests_collapse() {
        // two threads reporting the same best coordinate (possible only
        // if the selection itself repeated) collapse to one accept —
        // the unique-writer invariant of the Update phase
        let twin = ThreadBest {
            j: 4,
            phi: -0.9,
            delta: 0.1,
        };
        let other = ThreadBest {
            j: 2,
            phi: -0.3,
            delta: 0.2,
        };
        let mut out = Vec::new();
        resolve(
            &mut ThreadGreedy,
            &[twin, other, twin],
            &[],
            |_| -0.5,
            &mut out,
        );
        assert_eq!(out, vec![4, 2]);
    }

    #[test]
    fn consider_prefers_lower_phi_and_is_deterministic_on_ties() {
        let mut b = ThreadBest::NONE;
        b.consider(5, -0.3, 0.1);
        b.consider(9, -0.3, 0.2); // tie: keeps first
        assert_eq!(b.j, 5);
        b.consider(2, -0.4, 0.3);
        assert_eq!(b.j, 2);
    }
}
