//! Step three: Accept (Sec. 2.3) — which proposals survive.
//!
//! `All` (SHOTGUN, COLORING, CCD/SCD) bypasses the proxy entirely;
//! `ThreadGreedy` keeps each thread's best proposal (the paper's novel
//! algorithm — no cross-thread synchronization); `GlobalBest` keeps the
//! single best across threads (GREEDY, synchronizing reduction);
//! `GlobalTopK` is the §7 extension: the best K *independently of which
//! thread proposed them*.

/// Accept policy. The engine evaluates `ThreadGreedy` inside each worker
/// (zero synchronization) and the global policies in the leader.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Acceptor {
    /// Accept every proposal.
    All,
    /// Each thread accepts the best (lowest phi) of its own chunk.
    ThreadGreedy,
    /// Single globally-best proposal (classic GREEDY).
    GlobalBest,
    /// Best `k` proposals across all threads (§7 extension).
    GlobalTopK(usize),
}

/// A per-thread reduction result: the best proposal seen by one worker.
#[derive(Clone, Copy, Debug)]
pub struct ThreadBest {
    pub j: u32,
    pub phi: f64,
    pub delta: f64,
}

impl ThreadBest {
    pub const NONE: ThreadBest = ThreadBest {
        j: u32::MAX,
        phi: f64::INFINITY,
        delta: 0.0,
    };

    #[inline]
    pub fn consider(&mut self, j: u32, phi: f64, delta: f64) {
        // Strictly-better keeps the first-seen on ties => deterministic.
        if phi < self.phi {
            *self = ThreadBest { j, phi, delta };
        }
    }

    pub fn is_some(&self) -> bool {
        self.j != u32::MAX && self.delta != 0.0
    }
}

/// Leader-side resolution of the global policies. `bests` holds each
/// worker's reduction; `selected`/`phi` give the full proposal table for
/// TopK. Fills `out` with the accepted J'.
///
/// J' must be duplicate-free (unique-writer invariant of the engine's
/// Update phase). `selected` is already deduplicated by the engine's
/// plan-time filter, which covers the `All` and `GlobalTopK` arms; the
/// bests-derived arm additionally collapses repeats here (first
/// occurrence wins, allocation-free — the set is at most one entry per
/// thread). The engine's Update phase double-checks with a debug
/// assertion.
pub fn resolve_global(
    acceptor: Acceptor,
    bests: &[ThreadBest],
    selected: &[u32],
    phi_of: impl Fn(u32) -> f64,
    out: &mut Vec<u32>,
) {
    out.clear();
    match acceptor {
        Acceptor::All => out.extend_from_slice(selected),
        Acceptor::ThreadGreedy => {
            for b in bests {
                if b.is_some() && !out.contains(&b.j) {
                    out.push(b.j);
                }
            }
        }
        Acceptor::GlobalBest => {
            let mut best = ThreadBest::NONE;
            for b in bests {
                if b.is_some() {
                    best.consider(b.j, b.phi, b.delta);
                }
            }
            if best.is_some() {
                out.push(best.j);
            }
        }
        Acceptor::GlobalTopK(k) => {
            // partial selection of the k most-negative phi values
            let mut scored: Vec<(f64, u32)> =
                selected.iter().map(|&j| (phi_of(j), j)).collect();
            let k = k.min(scored.len());
            if k == 0 {
                return;
            }
            scored.select_nth_unstable_by(k - 1, |a, b| {
                a.0.partial_cmp(&b.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.1.cmp(&b.1))
            });
            let mut top: Vec<(f64, u32)> = scored[..k].to_vec();
            // deterministic order (by j) and drop no-op proposals
            top.sort_by_key(|&(_, j)| j);
            for (phi, j) in top {
                if phi < 0.0 {
                    out.push(j);
                }
            }
        }
    }
}

impl Acceptor {
    pub fn name(&self) -> String {
        match self {
            Acceptor::All => "all".into(),
            Acceptor::ThreadGreedy => "thread-greedy".into(),
            Acceptor::GlobalBest => "global-best".into(),
            Acceptor::GlobalTopK(k) => format!("top{k}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bests() -> Vec<ThreadBest> {
        vec![
            ThreadBest {
                j: 3,
                phi: -0.5,
                delta: 0.1,
            },
            ThreadBest::NONE,
            ThreadBest {
                j: 7,
                phi: -0.9,
                delta: -0.2,
            },
        ]
    }

    #[test]
    fn all_passes_selection_through() {
        let mut out = Vec::new();
        resolve_global(Acceptor::All, &bests(), &[1, 2, 3], |_| 0.0, &mut out);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn thread_greedy_keeps_per_thread_bests() {
        let mut out = Vec::new();
        resolve_global(Acceptor::ThreadGreedy, &bests(), &[], |_| 0.0, &mut out);
        assert_eq!(out, vec![3, 7]); // thread 1 had nothing
    }

    #[test]
    fn global_best_takes_minimum_phi() {
        let mut out = Vec::new();
        resolve_global(Acceptor::GlobalBest, &bests(), &[], |_| 0.0, &mut out);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn topk_selects_most_negative() {
        let selected = [0u32, 1, 2, 3, 4];
        let phi = [-0.1, -0.9, 0.0, -0.5, -0.3];
        let mut out = Vec::new();
        resolve_global(
            Acceptor::GlobalTopK(3),
            &[],
            &selected,
            |j| phi[j as usize],
            &mut out,
        );
        assert_eq!(out, vec![1, 3, 4]); // sorted by j, phi<0 only
    }

    #[test]
    fn topk_drops_nonnegative_phi() {
        let selected = [0u32, 1];
        let phi = [0.0, 0.0];
        let mut out = Vec::new();
        resolve_global(
            Acceptor::GlobalTopK(2),
            &[],
            &selected,
            |j| phi[j as usize],
            &mut out,
        );
        assert!(out.is_empty());
    }

    #[test]
    fn prop_accepted_subset_of_selected() {
        // the framework invariant of Sec. 2.3: J' ⊆ J for every policy
        use crate::util::prop;
        prop::check("J' subset of J", 100, |rng, size| {
            let k = 2 + rng.below(2 * size.max(2));
            let sel_n = 1 + rng.below(k);
            let selected: Vec<u32> =
                rng.sample_distinct(k, sel_n).into_iter().map(|j| j as u32).collect();
            let phi: Vec<f64> = (0..k).map(|_| rng.range_f64(-1.0, 0.0)).collect();
            let threads = 1 + rng.below(6);
            // per-thread bests drawn from the selection chunks
            let bests: Vec<ThreadBest> = (0..threads)
                .map(|t| {
                    let lo = selected.len() * t / threads;
                    let hi = selected.len() * (t + 1) / threads;
                    let mut b = ThreadBest::NONE;
                    for &j in &selected[lo..hi] {
                        b.consider(j, phi[j as usize], 0.1);
                    }
                    b
                })
                .collect();
            let policies = [
                Acceptor::All,
                Acceptor::ThreadGreedy,
                Acceptor::GlobalBest,
                Acceptor::GlobalTopK(1 + rng.below(sel_n)),
            ];
            let sel_set: std::collections::HashSet<u32> =
                selected.iter().copied().collect();
            let mut out = Vec::new();
            for policy in policies {
                resolve_global(policy, &bests, &selected, |j| phi[j as usize], &mut out);
                for &j in &out {
                    if !sel_set.contains(&j) {
                        return Err(format!("{policy:?}: {j} not selected"));
                    }
                }
                // no duplicates in J'
                let uniq: std::collections::HashSet<u32> = out.iter().copied().collect();
                if uniq.len() != out.len() {
                    return Err(format!("{policy:?}: duplicate accepts {out:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn duplicate_thread_bests_collapse() {
        // two threads reporting the same best coordinate (possible only
        // if the selection itself repeated) collapse to one accept —
        // the unique-writer invariant of the Update phase
        let phi = |_j: u32| -0.5;
        let twin = ThreadBest {
            j: 4,
            phi: -0.9,
            delta: 0.1,
        };
        let other = ThreadBest {
            j: 2,
            phi: -0.3,
            delta: 0.2,
        };
        let mut out = Vec::new();
        resolve_global(
            Acceptor::ThreadGreedy,
            &[twin, other, twin],
            &[],
            phi,
            &mut out,
        );
        assert_eq!(out, vec![4, 2]);
    }

    #[test]
    fn consider_prefers_lower_phi_and_is_deterministic_on_ties() {
        let mut b = ThreadBest::NONE;
        b.consider(5, -0.3, 0.1);
        b.consider(9, -0.3, 0.2); // tie: keeps first
        assert_eq!(b.j, 5);
        b.consider(2, -0.4, 0.3);
        assert_eq!(b.j, 2);
    }
}
