//! The Propose step math (Sec. 3 / Algorithm 4), sparse backend.
//!
//! For a coordinate j at the current iterate, compute
//!
//!   g      = <ell'(y, z), X_j> / n
//!   delta  = -psi(w_j; (g - lam)/beta_j, (g + lam)/beta_j)   (Eq. 7)
//!   phi    = beta_j/2 delta^2 + g delta
//!            + lam (|w_j + delta| - |w_j|)                   (Eq. 9)
//!
//! Two gradient paths exist: from a *precomputed* dloss vector (one
//! `ell'` evaluation per sample per iteration, shared by all selected
//! coordinates) or *on the fly* from `z` (one `ell'` per column nonzero —
//! cheaper when few coordinates are selected). The engine chooses per
//! iteration; both are tested equal here.
//!
//! All shared-state access here is **plain** (non-atomic): Propose and
//! the dloss refresh run in phases where `w`, `z` and `dloss` have no
//! concurrent writer, and `delta`/`phi`/`dloss` writes go to elements
//! this thread uniquely owns (see the engine's phase protocol and
//! [`crate::util::atomic::SyncF64Vec`]).

use super::problem::{Problem, SharedState};
use crate::kernel::KernelMode;
use crate::util::clip_psi;

/// A computed proposal for one coordinate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Proposal {
    pub j: usize,
    pub g: f64,
    pub delta: f64,
    /// Eq. (9) proxy: approximate objective change (<= 0).
    pub phi: f64,
}

/// Eq. (7) + Eq. (9) from a precomputed gradient.
#[inline]
pub fn proposal_from_gradient(problem: &Problem, j: usize, wj: f64, g: f64) -> Proposal {
    let lam = problem.lam;
    let beta = problem.beta_j(j);
    let delta = -clip_psi(wj, (g - lam) / beta, (g + lam) / beta);
    let phi = 0.5 * beta * delta * delta
        + g * delta
        + lam * ((wj + delta).abs() - wj.abs());
    Proposal { j, g, delta, phi }
}

/// Gradient along j from the cached dloss vector (Algorithm 4's
/// thread-local dot product).
#[inline]
pub fn gradient_from_dloss(problem: &Problem, state: &SharedState, j: usize) -> f64 {
    let (rows, vals) = problem.x.col(j);
    let mut acc = 0.0;
    for (&i, &v) in rows.iter().zip(vals) {
        acc += v * state.dloss.get(i as usize);
    }
    acc / problem.n_samples() as f64
}

/// [`gradient_from_dloss`] through the unrolled gather kernel
/// ([`crate::sparse::CscMatrix::dot_col_fast`]) — the
/// `EngineConfig::fast_kernels` path. Re-associates the reduction, so
/// it is *not* bit-identical to the scalar gradient; the engine keeps
/// the scalar path as the default.
#[inline]
pub fn gradient_from_dloss_fast(problem: &Problem, state: &SharedState, j: usize) -> f64 {
    // SAFETY: Propose and screen phases have no dloss writer (the
    // engine's unique-writer-per-phase protocol); the slice is scoped
    // to this one kernel call.
    let d = unsafe { state.dloss.plain_slice() };
    problem.x.dot_col_fast(j, d) / problem.n_samples() as f64
}

/// [`gradient_from_dloss`] under the per-solve [`KernelMode`]: the
/// plain scalar reference, or the dispatched gather kernel (unrolled
/// scalar / AVX2 / AVX-512) via
/// [`dot_col_mode`](crate::sparse::CscMatrix::dot_col_mode). Every fast
/// tier re-associates the reduction — 1e-12 engine discipline.
#[inline]
pub fn gradient_from_dloss_mode(
    problem: &Problem,
    state: &SharedState,
    j: usize,
    mode: KernelMode,
) -> f64 {
    match mode {
        KernelMode::Reference => gradient_from_dloss(problem, state, j),
        KernelMode::Fast(tier) => {
            // SAFETY: Propose and screen phases have no dloss writer
            // (the engine's unique-writer-per-phase protocol); the
            // slice is scoped to this one kernel call.
            let d = unsafe { state.dloss.plain_slice() };
            problem.x.dot_col_tier(j, d, tier) / problem.n_samples() as f64
        }
    }
}

/// Gradient along j computed directly from `z` (on-the-fly `ell'`).
#[inline]
pub fn gradient_from_z(problem: &Problem, state: &SharedState, j: usize) -> f64 {
    let (rows, vals) = problem.x.col(j);
    let loss = problem.loss.as_ref();
    let mut acc = 0.0;
    for (&i, &v) in rows.iter().zip(vals) {
        let i = i as usize;
        acc += v * loss.deriv(problem.y[i], state.z.get(i));
    }
    acc / problem.n_samples() as f64
}

/// [`gradient_from_z`] unrolled 4-way with software prefetch on the
/// `z` gathers — the `EngineConfig::fast_kernels` on-the-fly path. The
/// `ell'` evaluations stay per-element (a virtual call each), but the
/// latency-bound part of this kernel is the random `z[rows[i]]`
/// gather, which prefetching and the split accumulator chain attack
/// exactly as in [`CscMatrix::dot_col_fast`]. Like that kernel it
/// re-associates the reduction, so it is **not** bit-identical to the
/// scalar path (scalar stays the bit-exactness reference).
///
/// [`CscMatrix::dot_col_fast`]: crate::sparse::CscMatrix::dot_col_fast
#[inline]
pub fn gradient_from_z_fast(problem: &Problem, state: &SharedState, j: usize) -> f64 {
    use crate::kernel::{prefetch_read, PREFETCH_DIST};
    let (rows, vals) = problem.x.col(j);
    let loss = problem.loss.as_ref();
    let y = &problem.y;
    // SAFETY: Propose and screen phases have no z writer (the engine's
    // unique-writer-per-phase protocol); the slice is scoped to this
    // one kernel call.
    let z = unsafe { state.z.plain_slice() };
    let len = rows.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i + 4 <= len {
        if i + PREFETCH_DIST < len {
            prefetch_read(&z[rows[i + PREFETCH_DIST] as usize]);
        }
        let (i0, i1, i2, i3) = (
            rows[i] as usize,
            rows[i + 1] as usize,
            rows[i + 2] as usize,
            rows[i + 3] as usize,
        );
        a0 += vals[i] * loss.deriv(y[i0], z[i0]);
        a1 += vals[i + 1] * loss.deriv(y[i1], z[i1]);
        a2 += vals[i + 2] * loss.deriv(y[i2], z[i2]);
        a3 += vals[i + 3] * loss.deriv(y[i3], z[i3]);
        i += 4;
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    while i < len {
        let ii = rows[i] as usize;
        acc += vals[i] * loss.deriv(y[ii], z[ii]);
        i += 1;
    }
    acc / problem.n_samples() as f64
}

/// Full proposal for coordinate j; `use_dloss` picks the gradient path.
#[inline]
pub fn propose(problem: &Problem, state: &SharedState, j: usize, use_dloss: bool) -> Proposal {
    let g = if use_dloss {
        gradient_from_dloss(problem, state, j)
    } else {
        gradient_from_z(problem, state, j)
    };
    let wj = state.w.get(j);
    proposal_from_gradient(problem, j, wj, g)
}

/// [`propose`] with the unrolled gather kernels on **both** gradient
/// paths (`EngineConfig::fast_kernels`): [`gradient_from_dloss_fast`]
/// when the dloss cache is fresh, [`gradient_from_z_fast`] on the fly.
#[inline]
pub fn propose_fast(
    problem: &Problem,
    state: &SharedState,
    j: usize,
    use_dloss: bool,
) -> Proposal {
    let g = if use_dloss {
        gradient_from_dloss_fast(problem, state, j)
    } else {
        gradient_from_z_fast(problem, state, j)
    };
    let wj = state.w.get(j);
    proposal_from_gradient(problem, j, wj, g)
}

/// [`gradient_from_z`] under the per-solve [`KernelMode`]. The
/// on-the-fly path evaluates `ell'` per element through a virtual call,
/// which no SIMD tier can vectorize — every `Fast` tier therefore runs
/// the unrolled+prefetching [`gradient_from_z_fast`] arm (the gather
/// latency, not the arithmetic, is what that kernel attacks).
#[inline]
pub fn gradient_from_z_mode(
    problem: &Problem,
    state: &SharedState,
    j: usize,
    mode: KernelMode,
) -> f64 {
    match mode {
        KernelMode::Reference => gradient_from_z(problem, state, j),
        KernelMode::Fast(_) => gradient_from_z_fast(problem, state, j),
    }
}

/// [`propose`] under the per-solve [`KernelMode`]: dispatches both
/// gradient paths ([`gradient_from_dloss_mode`],
/// [`gradient_from_z_mode`]). `KernelMode::Reference` is exactly
/// [`propose`]; `Fast(KernelTier::Scalar)` is exactly [`propose_fast`].
#[inline]
pub fn propose_mode(
    problem: &Problem,
    state: &SharedState,
    j: usize,
    use_dloss: bool,
    mode: KernelMode,
) -> Proposal {
    let g = if use_dloss {
        gradient_from_dloss_mode(problem, state, j, mode)
    } else {
        gradient_from_z_mode(problem, state, j, mode)
    };
    let wj = state.w.get(j);
    proposal_from_gradient(problem, j, wj, g)
}

/// Refresh the cached dloss vector over the sample range `lo..hi`
/// (workers call this on disjoint chunks).
pub fn refresh_dloss(problem: &Problem, state: &SharedState, lo: usize, hi: usize) {
    let loss = problem.loss.as_ref();
    for i in lo..hi {
        let d = loss.deriv(problem.y[i], state.z.get(i));
        state.dloss.set(i, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::{Logistic, Squared};
    use crate::sparse::csc::small_fixture;
    use crate::sparse::io::Dataset;
    use crate::util::prop;

    fn problem(lam: f64) -> Problem {
        let ds = Dataset {
            x: small_fixture(),
            y: vec![1.0, -1.0, 1.0, -1.0],
            name: "t".into(),
        };
        Problem::new(ds, Box::new(Logistic), lam)
    }

    #[test]
    fn gradient_paths_agree() {
        let p = problem(0.01);
        let s = SharedState::from_warm_start(&p, &[0.2, -0.1, 0.4]);
        refresh_dloss(&p, &s, 0, p.n_samples());
        for j in 0..3 {
            let a = gradient_from_dloss(&p, &s, j);
            let b = gradient_from_z(&p, &s, j);
            assert!((a - b).abs() < 1e-14, "j={j}: {a} vs {b}");
            let full = crate::loss::full_gradient(
                p.loss.as_ref(),
                &p.x,
                &p.y,
                &s.z_snapshot(),
            );
            assert!((a - full[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn fast_gradient_path_matches_scalar() {
        let p = problem(0.01);
        let s = SharedState::from_warm_start(&p, &[0.2, -0.1, 0.4]);
        refresh_dloss(&p, &s, 0, p.n_samples());
        for j in 0..3 {
            let scalar = gradient_from_dloss(&p, &s, j);
            let fast = gradient_from_dloss_fast(&p, &s, j);
            assert!((scalar - fast).abs() < 1e-14, "j={j}: {scalar} vs {fast}");
            let a = propose(&p, &s, j, true);
            let b = propose_fast(&p, &s, j, true);
            assert!((a.delta - b.delta).abs() < 1e-12);
            assert!((a.phi - b.phi).abs() < 1e-12);
            // the on-the-fly arm is unrolled too now: same agreement
            // bar as the dloss arm (re-associated, not bit-identical)
            let zf = gradient_from_z_fast(&p, &s, j);
            let zs = gradient_from_z(&p, &s, j);
            assert!((zf - zs).abs() < 1e-14, "j={j}: {zs} vs {zf}");
            let a = propose(&p, &s, j, false);
            let b = propose_fast(&p, &s, j, false);
            assert!((a.delta - b.delta).abs() < 1e-12);
            assert!((a.phi - b.phi).abs() < 1e-12);
        }
    }

    #[test]
    fn mode_dispatch_matches_named_paths() {
        use crate::kernel::KernelTier;
        let p = problem(0.01);
        let s = SharedState::from_warm_start(&p, &[0.2, -0.1, 0.4]);
        refresh_dloss(&p, &s, 0, p.n_samples());
        for j in 0..3 {
            for use_dloss in [true, false] {
                // Reference mode is bit-identical to the scalar path
                let a = propose(&p, &s, j, use_dloss);
                let r = propose_mode(&p, &s, j, use_dloss, KernelMode::Reference);
                assert_eq!(a, r, "reference j={j}");
                // Fast(Scalar) is bit-identical to the unrolled path
                let f = propose_fast(&p, &s, j, use_dloss);
                let m = propose_mode(&p, &s, j, use_dloss, KernelMode::Fast(KernelTier::Scalar));
                assert_eq!(f, m, "fast-scalar j={j}");
                // SIMD tiers agree within the 1e-12 discipline
                for tier in [KernelTier::Avx2, KernelTier::Avx512] {
                    let t = propose_mode(&p, &s, j, use_dloss, KernelMode::Fast(tier));
                    assert!((a.g - t.g).abs() <= 1e-12 * a.g.abs().max(1.0), "{tier:?} j={j}");
                    assert!((a.delta - t.delta).abs() <= 1e-12, "{tier:?} j={j}");
                }
            }
        }
    }

    #[test]
    fn fast_onthefly_gradient_handles_wide_columns() {
        // columns longer than the unroll width + prefetch distance, so
        // the unrolled body, the prefetch branch and the scalar tail
        // all execute
        let mut rng = crate::util::Pcg64::seeded(17);
        let n = 200usize;
        let mut b = crate::sparse::CooBuilder::new(n, 4);
        for j in 0..4 {
            for i in 0..n {
                if rng.next_f64() < 0.6 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let ds = Dataset {
            x: b.build(),
            y: (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect(),
            name: "t".into(),
        };
        let p = Problem::new(ds, Box::new(Logistic), 1e-3);
        let w0: Vec<f64> = (0..4).map(|j| 0.1 * j as f64).collect();
        let s = SharedState::from_warm_start(&p, &w0);
        for j in 0..4 {
            let scalar = gradient_from_z(&p, &s, j);
            let fast = gradient_from_z_fast(&p, &s, j);
            let tol = 1e-12 * scalar.abs().max(1e-12);
            assert!((scalar - fast).abs() <= tol, "j={j}: {scalar} vs {fast}");
        }
    }

    #[test]
    fn proposal_zero_weight_zero_gradient() {
        let p = problem(0.5);
        // with w=0 and |g| <= lam, delta must be 0 (soft-threshold dead zone)
        let prop = proposal_from_gradient(&p, 0, 0.0, 0.3);
        assert_eq!(prop.delta, 0.0);
        assert_eq!(prop.phi, 0.0);
    }

    #[test]
    fn proposal_pulls_toward_minimizer() {
        let p = problem(0.01);
        // strong negative gradient => positive step
        let prop = proposal_from_gradient(&p, 0, 0.0, -2.0);
        assert!(prop.delta > 0.0);
        assert!(prop.phi < 0.0);
    }

    #[test]
    fn prop_phi_nonpositive_and_delta_optimal() {
        prop::check("phi <= 0 and delta minimizes bound", 200, |rng, _| {
            let p = problem(rng.range_f64(1e-4, 0.5));
            let j = rng.below(3);
            let wj = rng.range_f64(-2.0, 2.0);
            let g = rng.range_f64(-3.0, 3.0);
            let pr = proposal_from_gradient(&p, j, wj, g);
            if pr.phi > 1e-12 {
                return Err(format!("phi {} > 0", pr.phi));
            }
            // delta minimizes q(d) = beta/2 d^2 + g d + lam|w+d| (- lam|w|)
            let beta = p.beta_j(j);
            let q = |d: f64| {
                0.5 * beta * d * d + g * d + p.lam * ((wj + d).abs() - wj.abs())
            };
            let qd = q(pr.delta);
            for step in [1e-4, 1e-2, 0.3] {
                if qd > q(pr.delta + step) + 1e-9 || qd > q(pr.delta - step) + 1e-9 {
                    return Err(format!(
                        "delta {} not a minimizer (w={wj} g={g} lam={} beta={beta})",
                        pr.delta, p.lam
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_single_update_descends() {
        // applying one proposal never increases the true objective
        prop::check("single coordinate update descends", 100, |rng, _| {
            let lam = rng.range_f64(1e-4, 0.1);
            let loss: Box<dyn crate::loss::Loss> = if rng.next_f64() < 0.5 {
                Box::new(Logistic)
            } else {
                Box::new(Squared)
            };
            let ds = Dataset {
                x: small_fixture(),
                y: vec![1.0, -1.0, 1.0, -1.0],
                name: "t".into(),
            };
            let p = Problem::new(ds, loss, lam);
            let w0: Vec<f64> = (0..3).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let s = SharedState::from_warm_start(&p, &w0);
            refresh_dloss(&p, &s, 0, 4);
            let j = rng.below(3);
            let pr = propose(&p, &s, j, true);
            let z0 = s.z_snapshot();
            let f0 = p.objective(&w0, &z0);
            let mut w1 = w0.clone();
            w1[j] += pr.delta;
            let z1 = p.x.matvec(&w1);
            let f1 = p.objective(&w1, &z1);
            prop::ensure(
                f1 <= f0 + 1e-10,
                format!("objective rose {f0} -> {f1} (j={j}, delta={})", pr.delta),
            )
        });
    }
}
