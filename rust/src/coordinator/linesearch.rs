//! Step four's "Improve delta_j" (Algorithm 3 / Sec. 4.1).
//!
//! The paper refines each accepted increment with 500 further
//! quadratic-approximation steps. Along a single coordinate this is
//! cheap in sparse form: each step re-evaluates `ell'` only on the
//! column's support, using a *local* view `z + delta_total * X_j`
//! (other coordinates held fixed — matches the L2 `linesearch` artifact,
//! which is validated against the same semantics).

use std::sync::atomic::Ordering::Relaxed;

use super::problem::{Problem, SharedState};
use crate::util::clip_psi;

/// Refine a proposed increment for coordinate j by `steps` further
/// Eq. (7) iterations. Returns the refined *total* increment.
///
/// Runs inside the Update phase, so `z` is read through the *atomic*
/// view: in the engine's atomic update mode other threads' `fetch_add`
/// scatters race these reads benignly, exactly as in the OpenMP
/// original. (In the buffered and conflict-free modes the reads happen
/// to be conflict-free, but atomic loads cost the same as plain ones on
/// x86/ARM, so one discipline serves all three.) `w[j]` is owned by the
/// calling thread for the whole phase — plain read.
pub fn refine(
    problem: &Problem,
    state: &SharedState,
    j: usize,
    delta0: f64,
    steps: usize,
) -> f64 {
    if steps == 0 {
        return delta0;
    }
    let (rows, vals) = problem.x.col(j);
    if rows.is_empty() {
        return delta0;
    }
    let loss = problem.loss.as_ref();
    let lam = problem.lam;
    let beta = problem.beta_j(j);
    let inv_n = 1.0 / problem.n_samples() as f64;
    let wj0 = state.w.get(j);

    // local copy of z restricted to the support
    let mut zloc: Vec<f64> = rows
        .iter()
        .map(|&i| state.z[i as usize].load(Relaxed))
        .collect();
    for (zl, &v) in zloc.iter_mut().zip(vals) {
        *zl += delta0 * v;
    }

    let mut total = delta0;
    for _ in 0..steps {
        let mut g = 0.0;
        for ((&i, &v), &zl) in rows.iter().zip(vals).zip(&zloc) {
            g += v * loss.deriv(problem.y[i as usize], zl);
        }
        g *= inv_n;
        let wj = wj0 + total;
        let step = -clip_psi(wj, (g - lam) / beta, (g + lam) / beta);
        if step == 0.0 {
            break; // converged along this coordinate
        }
        total += step;
        for (zl, &v) in zloc.iter_mut().zip(vals) {
            *zl += step * v;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::propose::{propose, refresh_dloss};
    use crate::loss::{Logistic, Squared};
    use crate::sparse::csc::small_fixture;
    use crate::sparse::io::Dataset;
    use crate::util::prop;

    fn problem(loss_sq: bool, lam: f64) -> Problem {
        let ds = Dataset {
            x: small_fixture(),
            y: vec![1.0, -1.0, 1.0, -1.0],
            name: "t".into(),
        };
        let loss: Box<dyn crate::loss::Loss> =
            if loss_sq { Box::new(Squared) } else { Box::new(Logistic) };
        Problem::new(ds, loss, lam)
    }

    #[test]
    fn zero_steps_is_identity() {
        let p = problem(false, 0.01);
        let s = SharedState::new(4, 3);
        assert_eq!(refine(&p, &s, 0, 0.37, 0), 0.37);
    }

    #[test]
    fn squared_loss_converges_in_one_step_from_exact() {
        // for squared loss with normalized-free beta_j = ||X_j||^2, the
        // Eq. (7) step is the exact coordinate minimizer — refinement
        // must not move it.
        let p = problem(true, 0.01);
        let s = SharedState::new(4, 3);
        refresh_dloss(&p, &s, 0, 4);
        for j in 0..3 {
            let pr = propose(&p, &s, j, true);
            let refined = refine(&p, &s, j, pr.delta, 50);
            assert!(
                (refined - pr.delta).abs() < 1e-10,
                "j={j}: {} -> {refined}",
                pr.delta
            );
        }
    }

    #[test]
    fn prop_refinement_descends_single_coordinate() {
        prop::check("line search improves the 1-d objective", 80, |rng, _| {
            let lam = rng.range_f64(1e-4, 0.1);
            let p = problem(rng.next_f64() < 0.5, lam);
            let w0: Vec<f64> = (0..3).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let s = SharedState::from_warm_start(&p, &w0);
            refresh_dloss(&p, &s, 0, 4);
            let j = rng.below(3);
            let pr = propose(&p, &s, j, true);
            let steps = rng.below(30);
            let refined = refine(&p, &s, j, pr.delta, steps);

            // objective along coordinate j only
            let eval = |d: f64| {
                let mut w = w0.clone();
                w[j] += d;
                let z = p.x.matvec(&w);
                p.objective(&w, &z)
            };
            let f_prop = eval(pr.delta);
            let f_ref = eval(refined);
            prop::ensure(
                f_ref <= f_prop + 1e-10,
                format!("j={j} steps={steps}: {f_prop} -> {f_ref}"),
            )
        });
    }

    #[test]
    fn long_refinement_approaches_coordinate_optimum() {
        let p = problem(false, 1e-3);
        let w0 = vec![0.3, -0.2, 0.1];
        let s = SharedState::from_warm_start(&p, &w0);
        refresh_dloss(&p, &s, 0, 4);
        let j = 1;
        let pr = propose(&p, &s, j, true);
        let refined = refine(&p, &s, j, pr.delta, 500);
        // grid-search the true 1-d optimum
        let eval = |d: f64| {
            let mut w = w0.clone();
            w[j] += d;
            let z = p.x.matvec(&w);
            p.objective(&w, &z)
        };
        let grid_best = (-2000..=2000)
            .map(|t| eval(t as f64 * 1e-3))
            .fold(f64::INFINITY, f64::min);
        // the quadratic-bound iteration converges linearly; accept a
        // small residual gap vs the 1e-3-step grid optimum
        assert!(
            eval(refined) <= grid_best + 3e-4,
            "refined {} vs grid {}",
            eval(refined),
            grid_best
        );
    }
}
