//! Pathwise solving: a geometric lambda schedule with warm starts —
//! the "pathwise coordinate optimization" workload (Friedman et al.
//! 2007) the paper cites as the motivation for fast CD, and the
//! "decreasing regularization" schedule Bradley et al. suggest for
//! Shotgun (Sec. 4.1), offered as a first-class feature.

use std::sync::Arc;

use super::algorithms::{Algorithm, Preprocessed};
use super::engine::SolveOutput;
use crate::coloring::Strategy;
use crate::event::{emit, EventSink, Meta, PathStep};
use crate::loss::{self, Loss};
use crate::solver::Solver;
use crate::sparse::io::Dataset;

/// One point on the regularization path.
pub struct PathPoint {
    pub lam: f64,
    pub objective: f64,
    pub nnz: usize,
    pub updates: u64,
    pub elapsed_secs: f64,
    pub w: Vec<f64>,
}

/// Path configuration.
#[derive(Clone, Debug)]
pub struct PathConfig {
    pub algorithm: Algorithm,
    /// Points on the path (geometric between lam_max and
    /// lam_max * min_ratio).
    pub n_points: usize,
    /// Smallest lambda as a fraction of lambda_max.
    pub min_ratio: f64,
    pub threads: usize,
    /// Budget per path point.
    pub max_seconds: f64,
    pub max_iters: usize,
    /// Relative-improvement stop per point.
    pub tol: f64,
    pub line_search_steps: usize,
    pub seed: u64,
}

impl Default for PathConfig {
    fn default() -> Self {
        Self {
            algorithm: Algorithm::Shotgun,
            n_points: 10,
            min_ratio: 1e-3,
            threads: 4,
            max_seconds: 5.0,
            max_iters: usize::MAX,
            tol: 1e-7,
            line_search_steps: 0,
            seed: 1,
        }
    }
}

/// `lambda_max`: the smallest lambda whose optimum is all-zero —
/// `|grad F(0)|_inf` (KKT at w = 0).
pub fn lambda_max(x: &crate::sparse::CscMatrix, y: &[f64], loss: &dyn Loss) -> f64 {
    let z0 = vec![0.0; x.n_rows()];
    loss::full_gradient(loss, x, y, &z0)
        .iter()
        .fold(0.0f64, |m, g| m.max(g.abs()))
}

/// Solve the full path with warm starts. The dataset must already be
/// normalized if desired; preprocessing (P*, coloring) is shared across
/// all path points.
pub fn solve_path(
    ds: &Dataset,
    loss_name: &str,
    cfg: &PathConfig,
) -> anyhow::Result<Vec<PathPoint>> {
    solve_path_with(ds, loss_name, cfg, None)
}

/// [`solve_path`] with an event sink: one [`PathStep`] per completed
/// path point (`timestamp_ticks` = step index — logical, replayable).
pub fn solve_path_with(
    ds: &Dataset,
    loss_name: &str,
    cfg: &PathConfig,
    mut events: Option<&mut dyn EventSink>,
) -> anyhow::Result<Vec<PathPoint>> {
    let loss = loss::by_name(loss_name)?;
    let lmax = lambda_max(&ds.x, &ds.y, loss.as_ref());
    anyhow::ensure!(lmax > 0.0, "lambda_max = 0 (degenerate problem)");
    anyhow::ensure!(cfg.n_points >= 1, "need at least one path point");

    let pre = Arc::new(Preprocessed::for_algorithm(
        cfg.algorithm,
        &ds.x,
        Strategy::Greedy,
        cfg.seed,
    ));

    // geometric grid from lmax*ratio^(1/n) down to lmax*min_ratio
    let ratio = cfg.min_ratio.powf(1.0 / cfg.n_points as f64);
    let mut points = Vec::with_capacity(cfg.n_points);
    let mut warm: Vec<f64> = vec![0.0; ds.x.n_cols()];

    for step in 1..=cfg.n_points {
        let lam = lmax * ratio.powi(step as i32);
        // one builder per point; the expensive preprocessing (P*,
        // coloring) is injected so it is computed exactly once
        let out: SolveOutput = Solver::builder()
            .matrix(ds.x.clone())
            .labels(ds.y.clone())
            .boxed_loss(loss::by_name(loss_name)?)
            .lambda(lam)
            .algorithm(cfg.algorithm)
            .preprocessed(pre.clone())
            .threads(cfg.threads)
            .seed(cfg.seed.wrapping_add(step as u64))
            .line_search_steps(cfg.line_search_steps)
            .max_iters(cfg.max_iters)
            .max_seconds(cfg.max_seconds)
            .tol(cfg.tol)
            .warm_start(warm.clone())
            .build()?
            .solve();
        warm = out.w.clone();
        if let Some(sink) = events.as_deref_mut() {
            emit!(
                sink,
                Meta { timestamp_ticks: step as u64, shard: 0, thread: 0 },
                PathStep {
                    step: step as u64,
                    lambda: lam,
                    nnz: out.nnz as u64,
                    objective: out.objective,
                }
            );
        }
        points.push(PathPoint {
            lam,
            objective: out.objective,
            nnz: out.nnz,
            updates: out.metrics.updates,
            elapsed_secs: out.elapsed_secs,
            w: out.w,
        });
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::problem::{Problem, SharedState};
    use crate::data::{reuters_like, GenOptions};

    fn dataset() -> Dataset {
        let mut ds = reuters_like(&GenOptions::with_scale(0.015));
        ds.x.normalize_columns();
        ds
    }

    #[test]
    fn lambda_max_kills_everything() {
        let ds = dataset();
        let loss = loss::by_name("squared").unwrap();
        let lmax = lambda_max(&ds.x, &ds.y, loss.as_ref());
        assert!(lmax > 0.0);
        // solving AT lambda_max from zero: no coordinate escapes the
        // soft-threshold dead zone
        let problem = Problem::new(
            Dataset {
                x: ds.x.clone(),
                y: ds.y.clone(),
                name: ds.name.clone(),
            },
            loss::by_name("squared").unwrap(),
            lmax * 1.0001,
        );
        let state = SharedState::new(problem.n_samples(), problem.n_features());
        crate::coordinator::propose::refresh_dloss(&problem, &state, 0, problem.n_samples());
        for j in 0..problem.n_features() {
            let pr = crate::coordinator::propose::propose(&problem, &state, j, true);
            assert_eq!(pr.delta, 0.0, "coordinate {j} moved at lambda_max");
        }
    }

    #[test]
    fn nnz_monotone_ish_along_path() {
        let ds = dataset();
        let cfg = PathConfig {
            n_points: 5,
            min_ratio: 1e-2,
            threads: 2,
            max_seconds: 1.0,
            tol: 1e-8,
            ..Default::default()
        };
        let path = solve_path(&ds, "squared", &cfg).unwrap();
        assert_eq!(path.len(), 5);
        // lambdas strictly decreasing, nnz broadly growing
        for w in path.windows(2) {
            assert!(w[1].lam < w[0].lam);
        }
        assert!(
            path.last().unwrap().nnz >= path.first().unwrap().nnz,
            "nnz path: {:?}",
            path.iter().map(|p| p.nnz).collect::<Vec<_>>()
        );
        // warm starts: each point's weights are finite, objective finite
        for p in &path {
            assert!(p.objective.is_finite());
        }
    }

    #[test]
    fn path_steps_are_emitted_in_order() {
        use crate::event::{SolveInfo, StructuredLog, Subscribed};
        let ds = dataset();
        let cfg = PathConfig {
            n_points: 3,
            min_ratio: 0.05,
            threads: 1,
            max_seconds: 1.0,
            ..Default::default()
        };
        let log = StructuredLog::text();
        let mut sub = Subscribed::new(log.clone(), &SolveInfo::default());
        let path = solve_path_with(&ds, "squared", &cfg, Some(&mut sub)).unwrap();
        let lines = log.lines();
        assert_eq!(lines.len(), path.len());
        for (i, line) in lines.iter().enumerate() {
            assert!(line.contains(" path "), "{line}");
            assert!(line.contains(&format!("step={}", i + 1)), "{line}");
        }
    }

    #[test]
    fn warm_start_beats_cold_start_in_updates() {
        let ds = dataset();
        let cfg = PathConfig {
            n_points: 4,
            min_ratio: 0.05,
            threads: 1,
            max_seconds: 2.0,
            tol: 1e-9,
            seed: 3,
            ..Default::default()
        };
        let path = solve_path(&ds, "squared", &cfg).unwrap();
        let final_lam = path.last().unwrap().lam;
        // cold start directly at the final lambda, through the builder
        let cold = Solver::builder()
            .matrix(ds.x.clone())
            .labels(ds.y.clone())
            .boxed_loss(loss::by_name("squared").unwrap())
            .lambda(final_lam)
            .algorithm(Algorithm::Shotgun)
            .threads(1)
            .seed(3)
            .max_seconds(8.0)
            .tol(1e-9)
            .build()
            .unwrap()
            .solve();
        // warm-started final point reaches a comparable objective
        let warm_obj = path.last().unwrap().objective;
        assert!(
            warm_obj <= cold.objective * 1.05 + 1e-9,
            "warm {warm_obj} vs cold {}",
            cold.objective
        );
    }
}
