//! The GenCD coordinator — the paper's contribution (Sec. 2).
//!
//! Every iteration runs the four-step scheme of Algorithm 1:
//!
//! 1. **Select** a set `J` of coordinates ([`select`])
//! 2. **Propose** increments `delta_j` + proxies `phi_j` in parallel
//!    ([`propose`], Eq. 7/9)
//! 3. **Accept** a subset `J' ⊆ J` ([`accept`])
//! 4. **Update** `w`, `z` in parallel with atomic `z` adds ([`engine`],
//!    Algorithm 3), optionally refining each increment first
//!    ([`linesearch`], Sec. 4.1)
//!
//! [`algorithms`] maps the paper's named algorithms (Table 2) onto
//! policy pairs; [`engine`] is the OpenMP-analogue thread pool;
//! [`driver`] wires datasets, preprocessing (coloring, P*), and logging
//! into a single entry point.

pub mod accept;
pub mod algorithms;
pub mod convergence;
pub mod driver;
pub mod engine;
pub mod linesearch;
pub mod kkt;
pub mod metrics;
pub mod path;
pub mod problem;
pub mod propose;
pub mod select;

pub use algorithms::Algorithm;
pub use convergence::{History, Record};
pub use driver::{run, SolveResult};
pub use problem::Problem;
