//! The GenCD coordinator — the paper's contribution (Sec. 2).
//!
//! Every iteration runs the four-step scheme of Algorithm 1:
//!
//! 1. **Select** a set `J` of coordinates ([`select`])
//! 2. **Propose** increments `delta_j` + proxies `phi_j` in parallel
//!    ([`propose`], Eq. 7/9)
//! 3. **Accept** a subset `J' ⊆ J` ([`accept`])
//! 4. **Update** `w`, `z` in parallel with atomic `z` adds ([`engine`],
//!    Algorithm 3), optionally refining each increment first
//!    ([`linesearch`], Sec. 4.1)
//!
//! Select and Accept are *open* trait-based extension points
//! ([`select::Select`], [`accept::Accept`]); [`algorithms`] maps the
//! paper's named algorithms (Table 2) onto preset policy pairs;
//! [`engine`] is the OpenMP-analogue thread pool with per-iteration
//! [`observer::Observer`] hooks; [`driver`] wires datasets,
//! preprocessing (coloring, P*), and logging into a single
//! config-driven entry point. For embedding, prefer
//! [`crate::solver::SolverBuilder`].

pub mod accept;
pub mod algorithms;
pub mod convergence;
pub mod driver;
pub mod engine;
pub mod linesearch;
pub mod kkt;
pub mod metrics;
pub mod observer;
pub mod path;
pub mod problem;
pub mod propose;
pub mod select;

pub use accept::Accept;
pub use algorithms::Algorithm;
pub use convergence::{History, Record};
pub use driver::{run, SolveResult};
pub use observer::{IterationInfo, Observer};
pub use problem::Problem;
pub use select::Select;
