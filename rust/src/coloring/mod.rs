//! Structurally-independent feature detection via partial distance-2
//! coloring of the bipartite feature/sample graph (paper Appendix A and
//! Sec. 4.1, COLORING).
//!
//! Two features *conflict* when their columns share a nonzero row: then
//! concurrent updates to `z` would collide. A partial distance-2 coloring
//! on the feature side assigns conflicting features different colors, so
//! every color class can be updated with **no synchronization at all**
//! (not even atomics) — the property COLORING exploits.
//!
//! The paper's §7 notes that minimizing the *number* of colors is the
//! wrong objective for parallelism — balanced class sizes matter more —
//! so alongside the classic greedy heuristic we provide a
//! load-balancing variant ([`Strategy::Balanced`]).

pub mod speculative;
pub mod verify;

use crate::sparse::{CscMatrix, RowPattern};
use crate::util::{Pcg64, Timer};

/// Vertex-ordering and color-choice strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// First-fit greedy in natural feature order (classic heuristic;
    /// minimizes colors well).
    Greedy,
    /// First-fit greedy over a random feature permutation.
    GreedyRandomOrder,
    /// Largest-degree-first ordering (features touching the most samples
    /// colored first), first-fit choice.
    LargestFirst,
    /// Least-loaded admissible color (paper §7's "more balanced color
    /// distribution, even if ... a greater number of colors").
    Balanced,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Greedy => "greedy",
            Strategy::GreedyRandomOrder => "greedy-random",
            Strategy::LargestFirst => "largest-first",
            Strategy::Balanced => "balanced",
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "greedy" => Strategy::Greedy,
            "greedy-random" => Strategy::GreedyRandomOrder,
            "largest-first" => Strategy::LargestFirst,
            "balanced" => Strategy::Balanced,
            other => anyhow::bail!("unknown coloring strategy '{other}'"),
        })
    }
}

/// A feature coloring: `color[j]` is the class of feature j, and
/// `classes[c]` lists the features of color c.
#[derive(Clone, Debug)]
pub struct Coloring {
    pub color: Vec<u32>,
    pub classes: Vec<Vec<u32>>,
    pub strategy: Strategy,
    /// Wall-clock seconds of the preprocessing step (paper Table 3's
    /// "Time to color").
    pub elapsed_secs: f64,
}

impl Coloring {
    pub fn n_colors(&self) -> usize {
        self.classes.len()
    }

    /// Mean class size (paper Table 3's "Features/color").
    pub fn mean_class_size(&self) -> f64 {
        if self.classes.is_empty() {
            0.0
        } else {
            self.color.len() as f64 / self.classes.len() as f64
        }
    }

    pub fn max_class_size(&self) -> usize {
        self.classes.iter().map(|c| c.len()).max().unwrap_or(0)
    }

    pub fn min_class_size(&self) -> usize {
        self.classes.iter().map(|c| c.len()).min().unwrap_or(0)
    }

    /// Class-size imbalance: max/mean (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let mean = self.mean_class_size();
        if mean == 0.0 {
            1.0
        } else {
            self.max_class_size() as f64 / mean
        }
    }
}

/// Color the features of `x` (partial distance-2 on the feature side).
pub fn color_features(x: &CscMatrix, strategy: Strategy, seed: u64) -> Coloring {
    let timer = Timer::start();
    let k = x.n_cols();
    let rows = RowPattern::from_csc(x);

    // Feature visit order.
    let mut order: Vec<u32> = (0..k as u32).collect();
    match strategy {
        Strategy::Greedy | Strategy::Balanced => {}
        Strategy::GreedyRandomOrder => Pcg64::seeded(seed).shuffle(&mut order),
        Strategy::LargestFirst => {
            order.sort_by_key(|&j| std::cmp::Reverse(x.col_nnz(j as usize)));
        }
    }

    const UNCOLORED: u32 = u32::MAX;
    let mut color = vec![UNCOLORED; k];
    // forbidden[c] == j+1 marks color c as conflicting for current feature
    let mut forbidden: Vec<u32> = Vec::new();
    let mut loads: Vec<u32> = Vec::new(); // class sizes (Balanced)

    for (rank, &j) in order.iter().enumerate() {
        let stamp = rank as u32 + 1;
        // Mark colors of all distance-2 neighbors (features sharing a row).
        let (cols_rows, _) = x.col(j as usize);
        for &i in cols_rows {
            for &j2 in rows.row(i as usize) {
                let c = color[j2 as usize];
                if c != UNCOLORED {
                    if c as usize >= forbidden.len() {
                        forbidden.resize(c as usize + 1, 0);
                    }
                    forbidden[c as usize] = stamp;
                }
            }
        }
        let chosen = match strategy {
            Strategy::Balanced => {
                // least-loaded admissible color among the open ones; open a
                // new color only if every open color is forbidden.
                let mut best: Option<(u32, u32)> = None; // (load, color)
                for (c, &load) in loads.iter().enumerate() {
                    let is_forbidden =
                        c < forbidden.len() && forbidden[c] == stamp;
                    if !is_forbidden {
                        let cand = (load, c as u32);
                        if best.map_or(true, |b| cand < b) {
                            best = Some(cand);
                        }
                    }
                }
                match best {
                    Some((_, c)) => c,
                    None => {
                        loads.push(0);
                        (loads.len() - 1) as u32
                    }
                }
            }
            _ => {
                // first-fit: smallest non-forbidden color index
                let mut c = 0u32;
                while (c as usize) < forbidden.len() && forbidden[c as usize] == stamp {
                    c += 1;
                }
                c
            }
        };
        color[j as usize] = chosen;
        if strategy == Strategy::Balanced {
            loads[chosen as usize] += 1;
        }
    }

    // Build class lists.
    let n_colors = color.iter().map(|&c| c + 1).max().unwrap_or(0) as usize;
    let mut classes = vec![Vec::new(); n_colors];
    for (j, &c) in color.iter().enumerate() {
        classes[c as usize].push(j as u32);
    }

    Coloring {
        color,
        classes,
        strategy,
        elapsed_secs: timer.elapsed_secs(),
    }
}

#[cfg(test)]
mod tests {
    use super::verify::verify_coloring;
    use super::*;
    use crate::sparse::CooBuilder;
    use crate::util::prop;

    fn strategies() -> [Strategy; 4] {
        [
            Strategy::Greedy,
            Strategy::GreedyRandomOrder,
            Strategy::LargestFirst,
            Strategy::Balanced,
        ]
    }

    fn random_binary(rng: &mut Pcg64, n: usize, k: usize, p: f64) -> CscMatrix {
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < p {
                    b.push(i, j, 1.0);
                }
            }
        }
        b.build()
    }

    #[test]
    fn disjoint_columns_one_color() {
        // block-diagonal pattern: no conflicts at all
        let mut b = CooBuilder::new(6, 3);
        b.push(0, 0, 1.0);
        b.push(1, 0, 1.0);
        b.push(2, 1, 1.0);
        b.push(4, 2, 1.0);
        let m = b.build();
        for s in strategies() {
            let c = color_features(&m, s, 1);
            assert_eq!(c.n_colors(), 1, "{s:?}");
            assert!(verify_coloring(&m, &c).is_ok());
        }
    }

    #[test]
    fn dense_matrix_all_distinct() {
        // every pair of columns shares row 0 => k colors
        let mut b = CooBuilder::new(2, 5);
        for j in 0..5 {
            b.push(0, j, 1.0);
        }
        let m = b.build();
        for s in strategies() {
            let c = color_features(&m, s, 2);
            assert_eq!(c.n_colors(), 5, "{s:?}");
            assert!(verify_coloring(&m, &c).is_ok());
        }
    }

    #[test]
    fn prop_all_strategies_valid() {
        prop::check("coloring valid on random matrices", 40, |rng, size| {
            let n = 2 + rng.below(size.max(2));
            let k = 2 + rng.below(2 * size.max(2));
            let m = random_binary(rng, n, k, 0.2);
            for s in strategies() {
                let c = color_features(&m, s, rng.next_u64());
                if c.color.len() != k {
                    return Err(format!("{s:?}: wrong length"));
                }
                if let Err(e) = verify_coloring(&m, &c) {
                    return Err(format!("{s:?}: {e}"));
                }
                // every feature colored, classes partition features
                let total: usize = c.classes.iter().map(|cl| cl.len()).sum();
                if total != k {
                    return Err(format!("{s:?}: classes don't partition"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn balanced_no_worse_imbalance_usually() {
        // On a structured instance the balanced strategy must produce a
        // max/mean ratio no worse than plain greedy.
        let mut rng = Pcg64::seeded(77);
        let m = random_binary(&mut rng, 40, 200, 0.05);
        let g = color_features(&m, Strategy::Greedy, 1);
        let b = color_features(&m, Strategy::Balanced, 1);
        assert!(verify_coloring(&m, &b).is_ok());
        assert!(
            b.imbalance() <= g.imbalance() + 1e-9,
            "balanced {} vs greedy {}",
            b.imbalance(),
            g.imbalance()
        );
    }

    #[test]
    fn stats_consistent() {
        let mut rng = Pcg64::seeded(5);
        let m = random_binary(&mut rng, 20, 50, 0.1);
        let c = color_features(&m, Strategy::Greedy, 1);
        assert!(c.mean_class_size() > 0.0);
        assert!(c.max_class_size() >= c.min_class_size());
        assert!(c.imbalance() >= 1.0 - 1e-9);
        assert!(c.elapsed_secs >= 0.0);
    }

    #[test]
    fn empty_matrix() {
        let m = CooBuilder::new(4, 3).build();
        let c = color_features(&m, Strategy::Greedy, 1);
        // no conflicts anywhere: single color
        assert_eq!(c.n_colors(), 1);
    }
}
