//! Speculative (iterative) parallel distance-2 coloring — the algorithm
//! family of Catalyurek et al. that the paper's Appendix A builds on.
//!
//! Rounds of: (1) *tentative* coloring of all currently-uncolored
//! features in parallel chunks using a stale view of neighbor colors,
//! then (2) parallel *conflict detection* (same color, shared row), with
//! losers (the higher feature index, per the standard tie-break)
//! scheduled for the next round. Terminates because each round colors at
//! least one feature permanently; typically 2-4 rounds suffice.
//!
//! On this container the "parallel" chunks execute on a small thread
//! pool (correct at any thread count); the *algorithmic* structure —
//! stale reads, speculation, conflict repair — is exactly the
//! multi-core one, and the round/conflict counts it reports are
//! hardware-independent.

use std::sync::atomic::{AtomicU32, Ordering::Relaxed};

use super::{Coloring, Strategy};
use crate::sparse::{CscMatrix, RowPattern};
use crate::util::Timer;

const UNCOLORED: u32 = u32::MAX;

/// Outcome statistics of a speculative run.
#[derive(Clone, Copy, Debug)]
pub struct SpeculativeStats {
    pub rounds: usize,
    /// Total conflicts detected and repaired across rounds.
    pub conflicts: usize,
}

/// Speculatively color with `threads` workers. Returns the coloring and
/// round/conflict statistics.
pub fn color_speculative(
    x: &CscMatrix,
    threads: usize,
    // retained for API symmetry with color_features
    _seed: u64,
) -> (Coloring, SpeculativeStats) {
    let timer = Timer::start();
    let k = x.n_cols();
    let rows = RowPattern::from_csc(x);
    let threads = threads.max(1);

    let color: Vec<AtomicU32> = (0..k).map(|_| AtomicU32::new(UNCOLORED)).collect();
    let mut pending: Vec<u32> = (0..k as u32).collect();
    let mut rounds = 0usize;
    let mut conflicts_total = 0usize;

    while !pending.is_empty() {
        rounds += 1;
        // ---- phase 1: tentative coloring (parallel, stale reads) ------
        let chunk = (pending.len() + threads - 1) / threads;
        std::thread::scope(|scope| {
            for piece in pending.chunks(chunk) {
                scope.spawn(|| {
                    let mut forbidden: Vec<u32> = Vec::new();
                    for (stamp0, &j) in piece.iter().enumerate() {
                        let stamp = stamp0 as u32 + 1;
                        let (col_rows, _) = x.col(j as usize);
                        for &i in col_rows {
                            for &j2 in rows.row(i as usize) {
                                let c = color[j2 as usize].load(Relaxed);
                                if c != UNCOLORED {
                                    if c as usize >= forbidden.len() {
                                        forbidden.resize(c as usize + 1, 0);
                                    }
                                    forbidden[c as usize] = stamp;
                                }
                            }
                        }
                        let mut c = 0u32;
                        while (c as usize) < forbidden.len()
                            && forbidden[c as usize] == stamp
                        {
                            c += 1;
                        }
                        color[j as usize].store(c, Relaxed);
                    }
                });
            }
        });

        // ---- phase 2: conflict detection (parallel, disjoint rows) -----
        let n_rows = rows.n_rows();
        let row_chunk = (n_rows + threads - 1) / threads;
        let losers: Vec<Vec<u32>> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..threads {
                let lo = t * row_chunk;
                let hi = ((t + 1) * row_chunk).min(n_rows);
                let rows = &rows;
                let color = &color;
                handles.push(scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut seen: std::collections::HashMap<u32, u32> =
                        std::collections::HashMap::new();
                    for i in lo..hi {
                        seen.clear();
                        for &j in rows.row(i) {
                            let c = color[j as usize].load(Relaxed);
                            match seen.entry(c) {
                                std::collections::hash_map::Entry::Occupied(e) => {
                                    // higher index loses (standard tie-break)
                                    let j0 = *e.get();
                                    out.push(j.max(j0));
                                }
                                std::collections::hash_map::Entry::Vacant(v) => {
                                    v.insert(j);
                                }
                            }
                        }
                    }
                    out
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        let mut next: Vec<u32> = losers.into_iter().flatten().collect();
        next.sort_unstable();
        next.dedup();
        conflicts_total += next.len();
        for &j in &next {
            color[j as usize].store(UNCOLORED, Relaxed);
        }
        pending = next;
    }

    let color: Vec<u32> = color.iter().map(|c| c.load(Relaxed)).collect();
    let n_colors = color.iter().map(|&c| c + 1).max().unwrap_or(0) as usize;
    let mut classes = vec![Vec::new(); n_colors];
    for (j, &c) in color.iter().enumerate() {
        classes[c as usize].push(j as u32);
    }
    (
        Coloring {
            color,
            classes,
            strategy: Strategy::Greedy,
            elapsed_secs: timer.elapsed_secs(),
        },
        SpeculativeStats {
            rounds,
            conflicts: conflicts_total,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::verify::verify_coloring;
    use crate::sparse::CooBuilder;
    use crate::util::{prop, Pcg64};

    fn random_binary(rng: &mut Pcg64, n: usize, k: usize, p: f64) -> CscMatrix {
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < p {
                    b.push(i, j, 1.0);
                }
            }
        }
        b.build()
    }

    #[test]
    fn valid_on_random_matrices_any_thread_count() {
        prop::check("speculative coloring valid", 25, |rng, size| {
            let n = 2 + rng.below(size.max(2));
            let k = 2 + rng.below(2 * size.max(2));
            let m = random_binary(rng, n, k, 0.25);
            let threads = 1 + rng.below(8);
            let (c, stats) = color_speculative(&m, threads, 0);
            if let Err(e) = verify_coloring(&m, &c) {
                return Err(format!("threads={threads}: {e}"));
            }
            prop::ensure(stats.rounds >= 1, "no rounds")
        });
    }

    #[test]
    fn single_thread_no_conflicts() {
        // with one worker the stale view is never stale: zero conflicts
        let mut rng = Pcg64::seeded(4);
        let m = random_binary(&mut rng, 30, 120, 0.1);
        let (c, stats) = color_speculative(&m, 1, 0);
        assert!(verify_coloring(&m, &c).is_ok());
        assert_eq!(stats.conflicts, 0);
        assert_eq!(stats.rounds, 1);
    }

    #[test]
    fn comparable_color_count_to_sequential() {
        let mut rng = Pcg64::seeded(5);
        let m = random_binary(&mut rng, 40, 300, 0.08);
        let seq = crate::coloring::color_features(&m, Strategy::Greedy, 1);
        let (spec, _) = color_speculative(&m, 4, 0);
        assert!(verify_coloring(&m, &spec).is_ok());
        // speculative may need a few extra colors but not wildly more
        assert!(
            spec.n_colors() <= seq.n_colors() * 2 + 4,
            "spec {} vs seq {}",
            spec.n_colors(),
            seq.n_colors()
        );
    }

    #[test]
    fn dense_conflict_storm_terminates() {
        // every column shares row 0: maximal conflicts, k colors
        let mut b = CooBuilder::new(2, 24);
        for j in 0..24 {
            b.push(0, j, 1.0);
        }
        let m = b.build();
        let (c, stats) = color_speculative(&m, 8, 0);
        assert!(verify_coloring(&m, &c).is_ok());
        assert_eq!(c.n_colors(), 24);
        assert!(stats.rounds <= 25, "rounds {}", stats.rounds);
    }
}
