//! Coloring validation: the safety property COLORING's lock-free Update
//! step depends on. Used by tests and (optionally, `--verify-coloring`)
//! at solver startup.

use super::Coloring;
use crate::sparse::{CscMatrix, RowPattern};

/// Check that no two features with the same color share a row — i.e. the
/// coloring is a valid partial distance-2 coloring of the bipartite
/// graph. Returns a description of the first violation found.
pub fn verify_coloring(x: &CscMatrix, coloring: &Coloring) -> Result<(), String> {
    if coloring.color.len() != x.n_cols() {
        return Err(format!(
            "coloring covers {} features, matrix has {}",
            coloring.color.len(),
            x.n_cols()
        ));
    }
    let rows = RowPattern::from_csc(x);
    for i in 0..rows.n_rows() {
        let feats = rows.row(i);
        // all features sharing row i must have pairwise-distinct colors
        let mut seen: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
        for &j in feats {
            let c = coloring.color[j as usize];
            if let Some(&j0) = seen.get(&c) {
                return Err(format!(
                    "features {j0} and {j} share row {i} but both have color {c}"
                ));
            }
            seen.insert(c, j);
        }
    }
    Ok(())
}

/// Check that the class lists agree with the color array.
pub fn verify_classes(coloring: &Coloring) -> Result<(), String> {
    let mut seen = vec![false; coloring.color.len()];
    for (c, class) in coloring.classes.iter().enumerate() {
        for &j in class {
            if coloring.color[j as usize] != c as u32 {
                return Err(format!("feature {j} listed in class {c} but colored {}",
                    coloring.color[j as usize]));
            }
            if seen[j as usize] {
                return Err(format!("feature {j} in two classes"));
            }
            seen[j as usize] = true;
        }
    }
    if let Some(j) = seen.iter().position(|&s| !s) {
        return Err(format!("feature {j} in no class"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::{color_features, Strategy};
    use crate::sparse::CooBuilder;

    #[test]
    fn detects_conflict() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 0, 1.0);
        b.push(0, 1, 1.0);
        let m = b.build();
        let mut c = color_features(&m, Strategy::Greedy, 1);
        assert!(verify_coloring(&m, &c).is_ok());
        // corrupt: force both features into color 0
        c.color = vec![0, 0];
        assert!(verify_coloring(&m, &c).is_err());
    }

    #[test]
    fn detects_class_mismatch() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 0, 1.0);
        b.push(1, 1, 1.0);
        let m = b.build();
        let mut c = color_features(&m, Strategy::Greedy, 1);
        assert!(verify_classes(&c).is_ok());
        c.classes[0].clear();
        assert!(verify_classes(&c).is_err());
    }
}
