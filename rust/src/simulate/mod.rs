//! Thread-scalability cost model — the Figure 2 substitution.
//!
//! The paper measures updates/second on a 48-core Opteron for 1..32
//! threads. This container has a single core, so true multi-thread
//! *timing* is unobservable (the engine still runs correctly with any
//! thread count — correctness is tested with real oversubscribed
//! threads). Following DESIGN.md §4, Figure 2 is regenerated from an
//! analytic cost model whose per-operation constants are **calibrated
//! from measured single-thread runs** of the real engine, and whose
//! synchronization structure mirrors the implementation:
//!
//!   iter_time(T) = propose_max_chunk + accept(T) + update_max_chunk
//!                  + barriers_per_iter * barrier(T)
//!
//! * propose/update parallelize over static chunks (max over threads);
//! * GREEDY's accept is a serial critical-section reduction, linear in
//!   T (the paper's explanation for its flat scaling — Sec. 5.2);
//! * atomic `z` adds pay a contention premium proportional to the
//!   expected support overlap of concurrently-updated columns;
//! * barriers cost `O(log2 T)` (tree barrier).
//!
//! What the model is *for*: reproducing the relative shapes of Fig. 2
//! (who scales, who saturates, who stays flat) — not absolute Opteron
//! numbers.

use crate::sparse::{CscMatrix, RowPattern};

/// The *shape* of an accept policy, as the cost model sees it: which
/// serial reduction term the leader pays. Decoupled from the live
/// [`Accept`](crate::coordinator::accept::Accept) trait objects — the
/// model only needs the synchronization structure, not the policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcceptShape {
    /// Accept-everything (SHOTGUN, COLORING, CCD/SCD): no reduction.
    All,
    /// Per-thread best (THREAD-GREEDY): folded from padded slots.
    PerThread,
    /// Single global best (GREEDY): serial critical-section reduction.
    Single,
    /// Global top-K (§7): reduction plus a selection pass over |J|.
    TopK,
}

/// Calibrated per-operation costs (seconds).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-nonzero cost of the Propose traversal (gather + fma).
    pub propose_per_nnz: f64,
    /// Per-sample cost of a dloss refresh.
    pub dloss_per_sample: f64,
    /// Per-nonzero cost of the atomic Update scatter.
    pub update_per_nnz: f64,
    /// Per-coordinate fixed cost in Propose (Eq. 7/9 epilogue).
    pub propose_per_coord: f64,
    /// Serial per-thread cost of a critical-section reduction (GREEDY).
    pub reduce_per_thread: f64,
    /// Per-candidate cost of TopK selection.
    pub select_per_coord: f64,
    /// Base barrier latency and per-log2(T) increment.
    pub barrier_base: f64,
    pub barrier_per_log2t: f64,
    /// Multiplier on `update_per_nnz` per expected concurrent collision.
    pub atomic_contention: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Documented defaults in the right order of magnitude for a
        // 2010s x86 shared-memory node; calibrate() replaces the compute
        // constants with measured ones.
        Self {
            propose_per_nnz: 4e-9,
            dloss_per_sample: 8e-9,
            update_per_nnz: 6e-9,
            propose_per_coord: 8e-9,
            reduce_per_thread: 2.5e-7, // lock handoff + cacheline bounce
            select_per_coord: 2e-9,
            barrier_base: 4e-7,
            barrier_per_log2t: 3e-7,
            atomic_contention: 0.5,
        }
    }
}

impl CostModel {
    /// Replace the compute constants with values measured by the real
    /// engine (metrics phase timers from a single-thread run).
    pub fn calibrated(
        propose_secs: f64,
        propose_nnz: u64,
        proposals: u64,
        update_secs: f64,
        updates: u64,
        mean_col_nnz: f64,
    ) -> Self {
        let mut m = Self::default();
        if propose_nnz > 0 {
            // split propose time between traversal and per-coordinate
            // epilogue using the default ratio
            m.propose_per_nnz = 0.8 * propose_secs / propose_nnz as f64;
            if proposals > 0 {
                m.propose_per_coord = 0.2 * propose_secs / proposals as f64;
            }
        }
        if updates > 0 && mean_col_nnz > 0.0 {
            m.update_per_nnz = update_secs / (updates as f64 * mean_col_nnz);
        }
        m
    }
}

/// Per-(algorithm, dataset) iteration profile the model needs.
#[derive(Clone, Debug)]
pub struct IterProfile {
    /// Mean selected-set size |J|.
    pub selected: f64,
    /// Mean accepted-set size |J'| at T threads (callers pass a closure
    /// result; THREAD-GREEDY accepts exactly T).
    pub accepted_of_t: fn(f64, usize) -> f64,
    /// Accept-policy shape (determines the serial reduction term).
    pub acceptor: AcceptShape,
    /// Mean column nnz.
    pub mean_col_nnz: f64,
    /// Samples (dloss refresh size).
    pub n_samples: usize,
    /// Expected support overlap of two random columns (atomic
    /// contention driver); see [`expected_pairwise_overlap`].
    pub pairwise_overlap: f64,
    /// Barriers per iteration (5 in the engine).
    pub barriers: f64,
}

/// E[|supp(j1) ∩ supp(j2)|] for independent random columns = sum_i
/// (d_i / k)^2 where d_i is the row degree. COLORING's classes are
/// constructed to make this 0.
pub fn expected_pairwise_overlap(x: &CscMatrix) -> f64 {
    let rows = RowPattern::from_csc(x);
    let k = x.n_cols().max(1) as f64;
    (0..rows.n_rows())
        .map(|i| {
            let d = rows.row_nnz(i) as f64;
            (d / k) * (d / k)
        })
        .sum()
}

/// Predicted updates/second at `threads`.
pub fn updates_per_sec(m: &CostModel, p: &IterProfile, threads: usize) -> f64 {
    let t = threads.max(1);
    let tf = t as f64;
    let accepted = (p.accepted_of_t)(p.selected, t).max(0.0);

    // Propose: static chunks of |J|; the dloss-vs-on-the-fly heuristic
    // mirrors the engine's.
    let use_dloss = p.selected * p.mean_col_nnz >= p.n_samples as f64;
    let chunk = (p.selected / tf).ceil();
    let mut propose = chunk * (p.mean_col_nnz * m.propose_per_nnz + m.propose_per_coord);
    if use_dloss {
        propose += (p.n_samples as f64 / tf).ceil() * m.dloss_per_sample;
    }

    // Accept: policy-dependent serial work on the leader.
    let accept = match p.acceptor {
        AcceptShape::All | AcceptShape::PerThread => m.reduce_per_thread * tf * 0.25,
        AcceptShape::Single => m.reduce_per_thread * tf,
        AcceptShape::TopK => {
            m.reduce_per_thread * tf * 0.5 + p.selected * m.select_per_coord
        }
    };

    // Update: atomic scatter with contention from expected collisions.
    // Colliding writers among the (accepted/T per thread, T threads)
    // concurrent updates: approx (T-1) * overlap.
    let collisions = (tf - 1.0) * p.pairwise_overlap;
    let per_nnz = m.update_per_nnz * (1.0 + m.atomic_contention * collisions);
    let update = (accepted / tf).ceil() * p.mean_col_nnz * per_nnz;

    let barrier = m.barrier_base + m.barrier_per_log2t * (tf.log2().max(0.0));
    let iter_time = propose + accept + update + p.barriers * barrier;
    accepted / iter_time
}

/// Accepted-set-size closures for the paper's algorithms.
pub mod accepted {
    /// SHOTGUN / COLORING / CCD / SCD: accept everything selected.
    pub fn all(selected: f64, _t: usize) -> f64 {
        selected
    }

    /// THREAD-GREEDY: one per thread.
    pub fn per_thread(_selected: f64, t: usize) -> f64 {
        t as f64
    }

    /// GREEDY: single best.
    pub fn one(_selected: f64, _t: usize) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;

    fn profile(acceptor: AcceptShape, selected: f64, accepted_of_t: fn(f64, usize) -> f64) -> IterProfile {
        IterProfile {
            selected,
            accepted_of_t,
            acceptor,
            mean_col_nnz: 10.0,
            n_samples: 1000,
            pairwise_overlap: 0.05,
            barriers: 5.0,
        }
    }

    #[test]
    fn thread_greedy_scales_shotgun_saturates() {
        let m = CostModel::default();
        let tg = profile(AcceptShape::PerThread, 1024.0, accepted::per_thread);
        let sg = profile(AcceptShape::All, 23.0, accepted::all); // DOROTHEA P*
        let tg_speedup = updates_per_sec(&m, &tg, 32) / updates_per_sec(&m, &tg, 1);
        let sg_speedup = updates_per_sec(&m, &sg, 32) / updates_per_sec(&m, &sg, 1);
        assert!(
            tg_speedup > sg_speedup,
            "thread-greedy {tg_speedup} should outscale small-P* shotgun {sg_speedup}"
        );
        assert!(tg_speedup > 4.0, "thread-greedy speedup {tg_speedup}");
    }

    #[test]
    fn greedy_flattest() {
        // GREEDY's serial reduction caps scaling (paper Sec. 5.2)
        let m = CostModel::default();
        let gr = profile(AcceptShape::Single, 100_000.0, accepted::one);
        let tg = profile(AcceptShape::PerThread, 1024.0, accepted::per_thread);
        let gr_speedup = updates_per_sec(&m, &gr, 32) / updates_per_sec(&m, &gr, 1);
        let tg_speedup = updates_per_sec(&m, &tg, 32) / updates_per_sec(&m, &tg, 1);
        assert!(gr_speedup < tg_speedup);
        // and absolute updates/sec stays orders of magnitude below
        assert!(
            updates_per_sec(&m, &gr, 32) < updates_per_sec(&m, &tg, 32) / 10.0
        );
    }

    #[test]
    fn bigger_pstar_scales_further() {
        // REUTERS (P*=800) keeps gaining past where DOROTHEA (P*=23) stops
        let m = CostModel::default();
        let small = profile(AcceptShape::All, 23.0, accepted::all);
        let large = profile(AcceptShape::All, 800.0, accepted::all);
        let s = updates_per_sec(&m, &small, 32) / updates_per_sec(&m, &small, 8);
        let l = updates_per_sec(&m, &large, 32) / updates_per_sec(&m, &large, 8);
        assert!(l > s, "large-P* 8->32 gain {l} vs small {s}");
    }

    #[test]
    fn coloring_zero_overlap_beats_contended() {
        let m = CostModel::default();
        let mut contended = profile(AcceptShape::All, 22.0, accepted::all);
        contended.pairwise_overlap = 0.5;
        let mut clean = contended.clone();
        clean.pairwise_overlap = 0.0; // coloring guarantee
        assert!(
            updates_per_sec(&m, &clean, 16) > updates_per_sec(&m, &contended, 16)
        );
    }

    #[test]
    fn overlap_formula_matches_enumeration() {
        // 3 cols, rows shared: col0={0,1}, col1={0}, col2={1}
        let mut b = CooBuilder::new(2, 3);
        b.push(0, 0, 1.0);
        b.push(1, 0, 1.0);
        b.push(0, 1, 1.0);
        b.push(1, 2, 1.0);
        let x = b.build();
        // d_0 = 2 (cols 0,1), d_1 = 2 (cols 0,2); sum (d/k)^2 = 2*(2/3)^2
        let got = expected_pairwise_overlap(&x);
        assert!((got - 2.0 * (2.0 / 3.0) * (2.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn calibration_uses_measurements() {
        let m = CostModel::calibrated(1.0, 100_000_000, 1_000_000, 0.5, 100_000, 10.0);
        assert!((m.propose_per_nnz - 8e-9).abs() < 1e-12);
        assert!((m.update_per_nnz - 5e-10 * 1000.0).abs() < 1e-9);
        // non-measured constants keep defaults
        assert_eq!(m.barrier_base, CostModel::default().barrier_base);
    }

    #[test]
    fn monotone_in_work() {
        let m = CostModel::default();
        let p = profile(AcceptShape::All, 100.0, accepted::all);
        let mut heavier = p.clone();
        heavier.mean_col_nnz = 100.0;
        for t in [1, 4, 16] {
            assert!(updates_per_sec(&m, &p, t) > updates_per_sec(&m, &heavier, t));
        }
    }
}
