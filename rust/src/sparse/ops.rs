//! Cross-representation operations and property tests tying the sparse
//! substrate together.

use super::csc::CscMatrix;
use super::csr::CsrMatrix;

/// Frobenius norm.
pub fn frobenius(m: &CscMatrix) -> f64 {
    m.col_sq_norms().iter().sum::<f64>().sqrt()
}

/// Density = nnz / (rows * cols).
pub fn density(m: &CscMatrix) -> f64 {
    m.nnz() as f64 / (m.n_rows() as f64 * m.n_cols() as f64).max(1.0)
}

/// Verify CSC and CSR agree on every entry (used in integration tests).
pub fn csc_csr_consistent(csc: &CscMatrix, csr: &CsrMatrix) -> bool {
    if csc.n_rows() != csr.n_rows() || csc.n_cols() != csr.n_cols() {
        return false;
    }
    let mut nnz = 0usize;
    for i in 0..csr.n_rows() {
        let (cols, vals) = csr.row(i);
        nnz += cols.len();
        for (&j, &v) in cols.iter().zip(vals) {
            let (rows, cvals) = csc.col(j as usize);
            match rows.binary_search(&(i as u32)) {
                Ok(pos) => {
                    if cvals[pos] != v {
                        return false;
                    }
                }
                Err(_) => return false,
            }
        }
    }
    nnz == csc.nnz()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::CooBuilder;
    use crate::util::prop;

    fn random_matrix(rng: &mut crate::util::Pcg64, size: usize) -> CscMatrix {
        let n = 1 + rng.below(size.max(1));
        let k = 1 + rng.below(size.max(1));
        let nnz = rng.below(n * k + 1);
        let mut b = CooBuilder::new(n, k);
        for _ in 0..nnz {
            b.push(rng.below(n), rng.below(k), rng.range_f64(-2.0, 2.0));
        }
        b.build()
    }

    #[test]
    fn prop_csc_csr_roundtrip() {
        prop::check("csc<->csr consistent", 60, |rng, size| {
            let m = random_matrix(rng, size);
            let r = CsrMatrix::from_csc(&m);
            prop::ensure(
                csc_csr_consistent(&m, &r),
                format!("{}x{} nnz={}", m.n_rows(), m.n_cols(), m.nnz()),
            )
        });
    }

    #[test]
    fn prop_matvec_agree() {
        prop::check("X w via csc == via csr", 60, |rng, size| {
            let m = random_matrix(rng, size);
            let r = CsrMatrix::from_csc(&m);
            let w: Vec<f64> = (0..m.n_cols()).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let a = m.matvec(&w);
            let b: Vec<f64> = (0..m.n_rows()).map(|i| r.dot_row(i, &w)).collect();
            let ok = a
                .iter()
                .zip(&b)
                .all(|(x, y)| (x - y).abs() <= 1e-9 * (1.0 + x.abs()));
            prop::ensure(ok, format!("mismatch {a:?} vs {b:?}"))
        });
    }

    #[test]
    fn prop_normalize_then_unit() {
        prop::check("normalized columns have unit norm", 40, |rng, size| {
            let mut m = random_matrix(rng, size);
            m.normalize_columns();
            for sq in m.col_sq_norms() {
                if sq != 0.0 && (sq - 1.0).abs() > 1e-9 {
                    return Err(format!("col norm^2 {sq}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn density_and_frobenius() {
        let m = crate::sparse::csc::small_fixture();
        assert!((density(&m) - 6.0 / 12.0).abs() < 1e-12);
        assert!((frobenius(&m) - (17.0f64 + 34.0 + 40.0).sqrt()).abs() < 1e-12);
    }
}
