//! Compressed sparse column matrix — the design-matrix representation.
//!
//! Row indices are `u32` (the paper's datasets have n < 2^32 by a wide
//! margin) and values `f64`; a DOROTHEA-scale matrix (800 x 100 000,
//! 730k nnz) is ~9 MB.
//!
//! The index/value slabs are `Arc`-shared so a matrix can hand out
//! **zero-copy column-range views** ([`CscMatrix::col_range_view`]):
//! a view re-bases a `(hi - lo + 1)`-entry copy of the column pointers
//! and shares the row/value slabs, so shard-per-socket execution
//! ([`crate::shard`]) slices a 100M-nnz matrix into per-shard
//! sub-matrices without duplicating a single nonzero. Mutation
//! ([`CscMatrix::normalize_columns`]) is copy-on-write via
//! `Arc::make_mut`, so views are never mutated from under their base
//! (or vice versa).

use std::sync::Arc;

use crate::kernel::{self, KernelMode, KernelTier};

/// CSC sparse matrix. Columns are the *features* of the learning problem.
#[derive(Clone, Debug)]
pub struct CscMatrix {
    n_rows: usize,
    n_cols: usize,
    /// `col_ptr[j]..col_ptr[j+1]` (plus `nnz_start`) indexes the entries
    /// of column j. Always re-based: `col_ptr[0] == 0`.
    col_ptr: Vec<usize>,
    /// Offset of column 0's first entry in the shared slabs — 0 for a
    /// directly-built matrix, the view base for a column-range view.
    nnz_start: usize,
    row_idx: Arc<Vec<u32>>,
    values: Arc<Vec<f64>>,
}

/// Semantic equality: same shape and same per-column contents. (Views
/// share oversized slabs, so field-wise equality would wrongly
/// distinguish a view from an identical standalone matrix.)
impl PartialEq for CscMatrix {
    fn eq(&self, other: &Self) -> bool {
        let (ap, ar, av) = self.parts();
        let (bp, br, bv) = other.parts();
        self.n_rows == other.n_rows
            && self.n_cols == other.n_cols
            && ap == bp
            && ar == br
            && av == bv
    }
}

impl CscMatrix {
    /// Build from raw CSC arrays. Validates invariants.
    pub fn from_parts(
        n_rows: usize,
        n_cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<u32>,
        values: Vec<f64>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(col_ptr.len() == n_cols + 1, "col_ptr length");
        anyhow::ensure!(col_ptr[0] == 0, "col_ptr[0] != 0");
        anyhow::ensure!(
            *col_ptr.last().unwrap() == row_idx.len(),
            "col_ptr tail != nnz"
        );
        anyhow::ensure!(row_idx.len() == values.len(), "idx/val length mismatch");
        anyhow::ensure!(
            col_ptr.windows(2).all(|w| w[0] <= w[1]),
            "col_ptr not monotone"
        );
        anyhow::ensure!(
            row_idx.iter().all(|&r| (r as usize) < n_rows),
            "row index out of bounds"
        );
        // rows sorted strictly within each column (no duplicates)
        for j in 0..n_cols {
            let rows = &row_idx[col_ptr[j]..col_ptr[j + 1]];
            anyhow::ensure!(
                rows.windows(2).all(|w| w[0] < w[1]),
                "column {j} rows not strictly sorted"
            );
        }
        Ok(Self {
            n_rows,
            n_cols,
            col_ptr,
            nnz_start: 0,
            row_idx: Arc::new(row_idx),
            values: Arc::new(values),
        })
    }

    /// Zero-copy view of the contiguous column range `lo..hi`: the
    /// returned matrix has `hi - lo` columns (view-local indices
    /// `0..hi-lo` map to base columns `lo..hi`) and **shares** the
    /// row-index/value slabs with `self` — only the `hi - lo + 1`
    /// column pointers are copied. Views are full-fledged matrices:
    /// every read path (`col`, `matvec`, `col_sq_norms`, …) works
    /// unchanged, and mutating either side copies-on-write.
    ///
    /// # Panics
    ///
    /// If `lo > hi` or `hi > n_cols` (a programming error in the
    /// caller's partitioning).
    pub fn col_range_view(&self, lo: usize, hi: usize) -> CscMatrix {
        assert!(
            lo <= hi && hi <= self.n_cols,
            "col_range_view: {lo}..{hi} out of bounds for {} columns",
            self.n_cols
        );
        let base = self.col_ptr[lo];
        CscMatrix {
            n_rows: self.n_rows,
            n_cols: hi - lo,
            col_ptr: self.col_ptr[lo..=hi].iter().map(|&p| p - base).collect(),
            nnz_start: self.nnz_start + base,
            row_idx: Arc::clone(&self.row_idx),
            values: Arc::clone(&self.values),
        }
    }

    /// Gather the listed columns into a new matrix whose column `b` is
    /// `self`'s column `cols[b]` — a one-time O(selection nnz) copy
    /// into fresh slabs. The shard layer uses this with a permutation
    /// so that *arbitrary* partitions (round-robin, min-overlap) become
    /// contiguous, after which per-shard [`Self::col_range_view`]s are
    /// zero-copy.
    pub fn select_columns(&self, cols: &[u32]) -> CscMatrix {
        let mut col_ptr = Vec::with_capacity(cols.len() + 1);
        col_ptr.push(0usize);
        let mut nnz = 0usize;
        for &j in cols {
            nnz += self.col_nnz(j as usize);
            col_ptr.push(nnz);
        }
        let mut row_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for &j in cols {
            let (r, v) = self.col(j as usize);
            row_idx.extend_from_slice(r);
            values.extend_from_slice(v);
        }
        CscMatrix {
            n_rows: self.n_rows,
            n_cols: cols.len(),
            col_ptr,
            nnz_start: 0,
            row_idx: Arc::new(row_idx),
            values: Arc::new(values),
        }
    }

    /// Rows (samples).
    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Columns (features).
    #[inline]
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Total stored entries (of this view — not of the shared slabs).
    #[inline]
    pub fn nnz(&self) -> usize {
        *self.col_ptr.last().unwrap()
    }

    /// Entries of column j: parallel slices (rows, values).
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let range =
            (self.nnz_start + self.col_ptr[j])..(self.nnz_start + self.col_ptr[j + 1]);
        (&self.row_idx[range.clone()], &self.values[range])
    }

    /// nnz of column j.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Mean nnz per column (the paper's "Nonzeros/feature").
    pub fn mean_col_nnz(&self) -> f64 {
        self.nnz() as f64 / self.n_cols.max(1) as f64
    }

    /// Squared L2 norm of each column.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.n_cols)
            .map(|j| {
                let (_, v) = self.col(j);
                v.iter().map(|x| x * x).sum()
            })
            .collect()
    }

    /// Scale each column to unit L2 norm in place (paper Sec. 4.4:
    /// "we normalized columns of the feature matrix"). Zero columns are
    /// left untouched. Returns the original norms.
    pub fn normalize_columns(&mut self) -> Vec<f64> {
        let mut norms = Vec::with_capacity(self.n_cols);
        // copy-on-write: a matrix whose slabs are shared with a view (or
        // that is itself a view) gets private slabs before mutating
        let start = self.nnz_start;
        let values = Arc::make_mut(&mut self.values);
        for j in 0..self.n_cols {
            let range = (start + self.col_ptr[j])..(start + self.col_ptr[j + 1]);
            let norm = values[range.clone()]
                .iter()
                .map(|x| x * x)
                .sum::<f64>()
                .sqrt();
            norms.push(norm);
            if norm > 0.0 {
                for v in &mut values[range] {
                    *v /= norm;
                }
            }
        }
        norms
    }

    /// y += alpha * X_j (scatter along one column) — the Update step's
    /// `z <- z + delta_j X_j` without atomics (single-thread path).
    #[inline]
    pub fn axpy_col(&self, j: usize, alpha: f64, y: &mut [f64]) {
        let (rows, vals) = self.col(j);
        for (&i, &v) in rows.iter().zip(vals) {
            y[i as usize] += alpha * v;
        }
    }

    /// [`axpy_col`](Self::axpy_col) through the unrolled scalar kernel
    /// ([`kernel::axpy_unrolled`]): 4-way unroll + software prefetch.
    /// The scattered `y[rows[i]] +=` RMWs hit distinct elements (rows
    /// are strictly sorted within a column), so the four unrolled
    /// updates are independent. Bit-identical to the scalar kernel
    /// (each element is touched once, no re-association) but gated
    /// behind `EngineConfig::fast_kernels` all the same, so the default
    /// engine binary path is byte-for-byte the seed's.
    pub fn axpy_col_fast(&self, j: usize, alpha: f64, y: &mut [f64]) {
        let (rows, vals) = self.col(j);
        kernel::axpy_unrolled(rows, vals, alpha, y);
    }

    /// [`axpy_col_fast`](Self::axpy_col_fast) writing through a raw
    /// base pointer instead of a `&mut` slice — the multi-thread
    /// conflict-free scatter's kernel (`EngineConfig::fast_kernels`).
    /// Same unroll, same prefetch, bit-identical arithmetic to the
    /// scalar kernel (each element touched once, no re-association).
    ///
    /// # Safety
    ///
    /// `y` must point to a live `f64` array indexable by every row of
    /// column `j`, and for the duration of the call no other thread may
    /// read or write the elements this column touches — the engine's
    /// conflict-free discipline (COLORING's color classes, or a single
    /// worker) provides exactly that: indices are disjoint across
    /// concurrent callers, which is sound for raw-pointer stores where
    /// overlapping `&mut [f64]` slices would not be.
    pub unsafe fn axpy_col_fast_ptr(&self, j: usize, alpha: f64, y: *mut f64) {
        let (rows, vals) = self.col(j);
        kernel::axpy_unrolled_ptr(rows, vals, alpha, y);
    }

    /// [`axpy_col_fast_ptr`](Self::axpy_col_fast_ptr) at an explicit
    /// [`KernelTier`] — the engine's conflict-free scatter under a
    /// dispatched SIMD tier. Every tier's axpy is bit-identical to the
    /// scalar scatter (see [`crate::kernel`]); SIMD gathers index with
    /// `i32`, so absurdly tall matrices clamp back to the unrolled arm.
    ///
    /// # Safety
    ///
    /// Same contract as [`Self::axpy_col_fast_ptr`].
    pub unsafe fn axpy_col_ptr_tier(&self, j: usize, alpha: f64, y: *mut f64, tier: KernelTier) {
        let (rows, vals) = self.col(j);
        let tier = if self.n_rows > i32::MAX as usize {
            KernelTier::Scalar
        } else {
            tier
        };
        // SAFETY: rows are strictly sorted and unique within a column
        // (from_parts invariant) and the caller guarantees y covers
        // them exclusively
        kernel::axpy_scatter_ptr(tier, rows, vals, alpha, y);
    }

    /// [`axpy_col`](Self::axpy_col) under a per-solve [`KernelMode`]:
    /// the plain scalar reference or the dispatched fast tier. All arms
    /// are bit-identical.
    pub fn axpy_col_mode(&self, j: usize, alpha: f64, y: &mut [f64], mode: KernelMode) {
        match mode {
            KernelMode::Reference => self.axpy_col(j, alpha, y),
            KernelMode::Fast(tier) => {
                assert!(y.len() >= self.n_rows, "axpy target shorter than n_rows");
                // SAFETY: y is exclusively borrowed and covers all rows
                unsafe { self.axpy_col_ptr_tier(j, alpha, y.as_mut_ptr(), tier) }
            }
        }
    }

    /// <X_j, d> (gather along one column) — the Propose step's gradient
    /// numerator.
    #[inline]
    pub fn dot_col(&self, j: usize, d: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        let mut acc = 0.0;
        for (&i, &v) in rows.iter().zip(vals) {
            acc += v * d[i as usize];
        }
        acc
    }

    /// [`dot_col`](Self::dot_col) through the unrolled scalar kernel
    /// ([`kernel::dot_unrolled`]): 4 independent accumulators and a
    /// software-prefetch hint [`kernel::PREFETCH_DIST`] gathers ahead —
    /// the gather is latency-bound on the random `d[rows[i]]` loads, so
    /// splitting the dependency chain and prefetching the upcoming
    /// lines is worth ~2x on wide columns (hotpath bench:
    /// `dot_col_unrolled_ns_per_nnz`).
    ///
    /// **Not bit-identical** to the scalar kernel: the 4 partial sums
    /// re-associate the floating-point reduction. The engine keeps the
    /// scalar path as the default and only switches here under
    /// `EngineConfig::fast_kernels`, so the T = 1 bit-exact differential
    /// tests pin the scalar kernel.
    pub fn dot_col_fast(&self, j: usize, d: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        kernel::dot_unrolled(rows, vals, d)
    }

    /// [`dot_col_fast`](Self::dot_col_fast) at an explicit
    /// [`KernelTier`]: the hardware-gather SIMD arms where dispatched,
    /// the unrolled kernel at `Scalar` (and as the automatic fallback
    /// for matrices too tall for `i32` gather offsets). Re-associates
    /// at every tier — 1e-12 discipline, like the unrolled kernel.
    pub fn dot_col_tier(&self, j: usize, d: &[f64], tier: KernelTier) -> f64 {
        assert!(d.len() >= self.n_rows, "dot operand shorter than n_rows");
        let (rows, vals) = self.col(j);
        // SAFETY: from_parts guarantees every row < n_rows <= d.len()
        unsafe { kernel::dot_gather(tier, rows, vals, d) }
    }

    /// [`dot_col`](Self::dot_col) under a per-solve [`KernelMode`].
    #[inline]
    pub fn dot_col_mode(&self, j: usize, d: &[f64], mode: KernelMode) -> f64 {
        match mode {
            KernelMode::Reference => self.dot_col(j, d),
            KernelMode::Fast(tier) => self.dot_col_tier(j, d, tier),
        }
    }

    /// Dense matvec `X w` (used by power iteration and tests).
    pub fn matvec(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.n_cols);
        let mut out = vec![0.0; self.n_rows];
        for j in 0..self.n_cols {
            let wj = w[j];
            if wj != 0.0 {
                self.axpy_col(j, wj, &mut out);
            }
        }
        out
    }

    /// Transposed matvec `X^T u`.
    pub fn matvec_t(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.n_rows);
        (0..self.n_cols).map(|j| self.dot_col(j, u)).collect()
    }

    /// Gather columns `js` into a dense column-major panel (n x B) of f32
    /// — the staging step for the DenseBlockHlo propose backend.
    /// `panel` must have length `n_rows * js.len()` and is fully
    /// overwritten.
    pub fn gather_panel_f32(&self, js: &[usize], panel: &mut [f32]) {
        assert_eq!(panel.len(), self.n_rows * js.len());
        panel.fill(0.0);
        for (b, &j) in js.iter().enumerate() {
            let base = b * self.n_rows;
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                panel[base + i as usize] = v as f32;
            }
        }
    }

    /// Dense representation (tests only; O(n*k) memory).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.n_cols]; self.n_rows];
        for j in 0..self.n_cols {
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                d[i as usize][j] = v;
            }
        }
        d
    }

    /// Internal accessors for sibling modules (io, csr conversion). The
    /// row/value slices are windowed to this view's entries, so the
    /// (re-based) column pointers index them directly for views too.
    pub(crate) fn parts(&self) -> (&[usize], &[u32], &[f64]) {
        let window = self.nnz_start..self.nnz_start + self.nnz();
        (
            &self.col_ptr,
            &self.row_idx[window.clone()],
            &self.values[window],
        )
    }
}

#[cfg(test)]
pub(crate) fn small_fixture() -> CscMatrix {
    // 4x3:
    //   [1 0 2]
    //   [0 3 0]
    //   [4 0 0]
    //   [0 5 6]
    CscMatrix::from_parts(
        4,
        3,
        vec![0, 2, 4, 6],
        vec![0, 2, 1, 3, 0, 3],
        vec![1.0, 4.0, 3.0, 5.0, 2.0, 6.0],
    )
    .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_parts_validates() {
        assert!(CscMatrix::from_parts(2, 1, vec![0, 1], vec![5], vec![1.0]).is_err());
        assert!(CscMatrix::from_parts(2, 1, vec![0], vec![], vec![]).is_err());
        assert!(
            CscMatrix::from_parts(2, 1, vec![0, 2], vec![1, 0], vec![1.0, 1.0]).is_err(),
            "unsorted rows must be rejected"
        );
        assert!(
            CscMatrix::from_parts(2, 1, vec![0, 2], vec![0, 0], vec![1.0, 1.0]).is_err(),
            "duplicate rows must be rejected"
        );
    }

    #[test]
    fn col_access() {
        let m = small_fixture();
        assert_eq!(m.n_rows(), 4);
        assert_eq!(m.n_cols(), 3);
        assert_eq!(m.nnz(), 6);
        let (rows, vals) = m.col(1);
        assert_eq!(rows, &[1, 3]);
        assert_eq!(vals, &[3.0, 5.0]);
        assert_eq!(m.col_nnz(2), 2);
        assert_eq!(m.mean_col_nnz(), 2.0);
    }

    #[test]
    fn dot_and_axpy() {
        let m = small_fixture();
        let d = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.dot_col(0, &d), 1.0 + 12.0);
        let mut y = [0.0; 4];
        m.axpy_col(2, 2.0, &mut y);
        assert_eq!(y, [4.0, 0.0, 0.0, 12.0]);
    }

    #[test]
    fn fast_kernels_match_scalar() {
        // wide random column set so the 4-way bodies, the remainder
        // loop and the prefetch guard all execute
        let n = 200usize;
        let mut rng = crate::util::Pcg64::seeded(9);
        let mut b = crate::sparse::CooBuilder::new(n, 12);
        for j in 0..12 {
            for i in 0..n {
                if rng.next_f64() < 0.4 {
                    b.push(i, j, rng.range_f64(-2.0, 2.0));
                }
            }
        }
        let m = b.build();
        let d: Vec<f64> = (0..n).map(|i| ((i * 7919) % 83) as f64 - 41.0).collect();
        for j in 0..12 {
            let scalar = m.dot_col(j, &d);
            let fast = m.dot_col_fast(j, &d);
            let tol = 1e-12 * scalar.abs().max(1.0);
            assert!(
                (scalar - fast).abs() <= tol,
                "dot j={j}: {scalar} vs {fast}"
            );
            let mut y0 = d.clone();
            let mut y1 = d.clone();
            m.axpy_col(j, 0.37, &mut y0);
            m.axpy_col_fast(j, 0.37, &mut y1);
            // axpy touches each element once: bit-identical
            assert_eq!(y0, y1, "axpy j={j}");
            // the raw-pointer variant (multi-thread conflict-free
            // scatter) is the same arithmetic again
            let mut y2 = d.clone();
            // SAFETY: single-threaded test, y2 live and long enough
            unsafe { m.axpy_col_fast_ptr(j, 0.37, y2.as_mut_ptr()) };
            assert_eq!(y0, y2, "axpy_ptr j={j}");
        }
        // degenerate columns: empty and shorter than the unroll width
        let tiny = small_fixture();
        for j in 0..3 {
            assert_eq!(
                tiny.dot_col(j, &[1.0, 2.0, 3.0, 4.0]),
                tiny.dot_col_fast(j, &[1.0, 2.0, 3.0, 4.0])
            );
        }
    }

    #[test]
    fn tier_kernels_match_scalar() {
        let n = 200usize;
        let mut rng = crate::util::Pcg64::seeded(10);
        let mut b = crate::sparse::CooBuilder::new(n, 8);
        for j in 0..8 {
            for i in 0..n {
                if rng.next_f64() < 0.4 {
                    b.push(i, j, rng.range_f64(-2.0, 2.0));
                }
            }
        }
        let m = b.build();
        let d: Vec<f64> = (0..n).map(|i| ((i * 6007) % 97) as f64 - 48.0).collect();
        for j in 0..8 {
            let scalar = m.dot_col(j, &d);
            let mut want = d.clone();
            m.axpy_col(j, 0.37, &mut want);
            for tier in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
                let got = m.dot_col_tier(j, &d, tier);
                let tol = 1e-12 * scalar.abs().max(1.0);
                assert!((scalar - got).abs() <= tol, "dot {tier:?} j={j}");
                // every axpy tier is bit-identical to the scalar scatter
                let mut y = d.clone();
                m.axpy_col_mode(j, 0.37, &mut y, KernelMode::Fast(tier));
                assert_eq!(y, want, "axpy {tier:?} j={j}");
            }
            // Reference mode is exactly the plain scalar path
            assert_eq!(
                m.dot_col_mode(j, &d, KernelMode::Reference).to_bits(),
                scalar.to_bits()
            );
        }
    }

    #[test]
    fn matvec_matches_dense() {
        let m = small_fixture();
        let w = [1.0, -1.0, 0.5];
        let got = m.matvec(&w);
        let dense = m.to_dense();
        for i in 0..4 {
            let want: f64 = (0..3).map(|j| dense[i][j] * w[j]).sum();
            assert!((got[i] - want).abs() < 1e-12);
        }
        let u = [1.0, 2.0, 3.0, 4.0];
        let got_t = m.matvec_t(&u);
        for j in 0..3 {
            let want: f64 = (0..4).map(|i| dense[i][j] * u[i]).sum();
            assert!((got_t[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn normalize_columns_unit_norm() {
        let mut m = small_fixture();
        let norms = m.normalize_columns();
        assert!((norms[0] - (17f64).sqrt()).abs() < 1e-12);
        for (j, _) in norms.iter().enumerate() {
            let (_, vals) = m.col(j);
            let n: f64 = vals.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn gather_panel() {
        let m = small_fixture();
        let mut panel = vec![9.0f32; 8];
        m.gather_panel_f32(&[2, 0], &mut panel);
        assert_eq!(panel, vec![2.0, 0.0, 0.0, 6.0, 1.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn col_sq_norms_match() {
        let m = small_fixture();
        assert_eq!(m.col_sq_norms(), vec![17.0, 34.0, 40.0]);
    }

    #[test]
    fn col_range_view_matches_base() {
        let m = small_fixture();
        let v = m.col_range_view(1, 3);
        assert_eq!(v.n_rows(), 4);
        assert_eq!(v.n_cols(), 2);
        assert_eq!(v.nnz(), 4);
        for local in 0..2 {
            assert_eq!(v.col(local), m.col(local + 1));
            assert_eq!(v.col_nnz(local), m.col_nnz(local + 1));
        }
        assert_eq!(v.col_sq_norms(), vec![34.0, 40.0]);
        // empty and full ranges are fine
        assert_eq!(m.col_range_view(2, 2).nnz(), 0);
        assert_eq!(m.col_range_view(0, 3), m);
        // a view of a view composes
        let vv = v.col_range_view(1, 2);
        assert_eq!(vv.col(0), m.col(2));
        // semantic equality: the view equals a standalone copy
        let standalone = m.select_columns(&[1, 2]);
        assert_eq!(v, standalone);
    }

    #[test]
    fn view_survives_base_normalization() {
        // copy-on-write: normalizing the base must not corrupt a live
        // view (the view keeps the original slabs)
        let mut m = small_fixture();
        let v = m.col_range_view(0, 3);
        let before = v.col(1).1.to_vec();
        m.normalize_columns();
        assert_eq!(v.col(1).1, &before[..], "view mutated by base CoW");
        let (_, vals) = m.col(1);
        let n: f64 = vals.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((n - 1.0).abs() < 1e-12, "base not normalized");
    }

    #[test]
    fn select_columns_permutes() {
        let m = small_fixture();
        let p = m.select_columns(&[2, 0, 1]);
        assert_eq!(p.n_cols(), 3);
        assert_eq!(p.col(0), m.col(2));
        assert_eq!(p.col(1), m.col(0));
        assert_eq!(p.col(2), m.col(1));
        assert_eq!(p.nnz(), m.nnz());
        // subsets work too
        let s = m.select_columns(&[1]);
        assert_eq!(s.n_cols(), 1);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.col(0), m.col(1));
        assert_eq!(m.select_columns(&[]).n_cols(), 0);
    }

    #[test]
    fn view_matvec_and_csr_roundtrip() {
        // views feed every downstream consumer: matvec and the
        // parts()-based CSR conversion must see only the view's columns
        let m = small_fixture();
        let v = m.col_range_view(1, 3);
        let got = v.matvec(&[1.0, 2.0]);
        let dense = m.to_dense();
        for i in 0..4 {
            let want = dense[i][1] + 2.0 * dense[i][2];
            assert!((got[i] - want).abs() < 1e-12);
        }
        let rp = crate::sparse::RowPattern::from_csc(&v);
        assert_eq!(rp.n_cols(), 2);
        assert_eq!(rp.row(3), &[0, 1], "row 3 holds view-local cols 0,1");
    }
}
