//! Compressed sparse column matrix — the design-matrix representation.
//!
//! Row indices are `u32` (the paper's datasets have n < 2^32 by a wide
//! margin) and values `f64`; a DOROTHEA-scale matrix (800 x 100 000,
//! 730k nnz) is ~9 MB.

/// CSC sparse matrix. Columns are the *features* of the learning problem.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n_rows: usize,
    n_cols: usize,
    /// `col_ptr[j]..col_ptr[j+1]` indexes the entries of column j.
    col_ptr: Vec<usize>,
    row_idx: Vec<u32>,
    values: Vec<f64>,
}

impl CscMatrix {
    /// Build from raw CSC arrays. Validates invariants.
    pub fn from_parts(
        n_rows: usize,
        n_cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<u32>,
        values: Vec<f64>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(col_ptr.len() == n_cols + 1, "col_ptr length");
        anyhow::ensure!(col_ptr[0] == 0, "col_ptr[0] != 0");
        anyhow::ensure!(
            *col_ptr.last().unwrap() == row_idx.len(),
            "col_ptr tail != nnz"
        );
        anyhow::ensure!(row_idx.len() == values.len(), "idx/val length mismatch");
        anyhow::ensure!(
            col_ptr.windows(2).all(|w| w[0] <= w[1]),
            "col_ptr not monotone"
        );
        anyhow::ensure!(
            row_idx.iter().all(|&r| (r as usize) < n_rows),
            "row index out of bounds"
        );
        // rows sorted strictly within each column (no duplicates)
        for j in 0..n_cols {
            let rows = &row_idx[col_ptr[j]..col_ptr[j + 1]];
            anyhow::ensure!(
                rows.windows(2).all(|w| w[0] < w[1]),
                "column {j} rows not strictly sorted"
            );
        }
        Ok(Self {
            n_rows,
            n_cols,
            col_ptr,
            row_idx,
            values,
        })
    }

    /// Rows (samples).
    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Columns (features).
    #[inline]
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Total stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Entries of column j: parallel slices (rows, values).
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let range = self.col_ptr[j]..self.col_ptr[j + 1];
        (&self.row_idx[range.clone()], &self.values[range])
    }

    /// nnz of column j.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Mean nnz per column (the paper's "Nonzeros/feature").
    pub fn mean_col_nnz(&self) -> f64 {
        self.nnz() as f64 / self.n_cols.max(1) as f64
    }

    /// Squared L2 norm of each column.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.n_cols)
            .map(|j| {
                let (_, v) = self.col(j);
                v.iter().map(|x| x * x).sum()
            })
            .collect()
    }

    /// Scale each column to unit L2 norm in place (paper Sec. 4.4:
    /// "we normalized columns of the feature matrix"). Zero columns are
    /// left untouched. Returns the original norms.
    pub fn normalize_columns(&mut self) -> Vec<f64> {
        let mut norms = Vec::with_capacity(self.n_cols);
        for j in 0..self.n_cols {
            let range = self.col_ptr[j]..self.col_ptr[j + 1];
            let norm = self.values[range.clone()]
                .iter()
                .map(|x| x * x)
                .sum::<f64>()
                .sqrt();
            norms.push(norm);
            if norm > 0.0 {
                for v in &mut self.values[range] {
                    *v /= norm;
                }
            }
        }
        norms
    }

    /// y += alpha * X_j (scatter along one column) — the Update step's
    /// `z <- z + delta_j X_j` without atomics (single-thread path).
    #[inline]
    pub fn axpy_col(&self, j: usize, alpha: f64, y: &mut [f64]) {
        let (rows, vals) = self.col(j);
        for (&i, &v) in rows.iter().zip(vals) {
            y[i as usize] += alpha * v;
        }
    }

    /// <X_j, d> (gather along one column) — the Propose step's gradient
    /// numerator.
    #[inline]
    pub fn dot_col(&self, j: usize, d: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        let mut acc = 0.0;
        for (&i, &v) in rows.iter().zip(vals) {
            acc += v * d[i as usize];
        }
        acc
    }

    /// Dense matvec `X w` (used by power iteration and tests).
    pub fn matvec(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.n_cols);
        let mut out = vec![0.0; self.n_rows];
        for j in 0..self.n_cols {
            let wj = w[j];
            if wj != 0.0 {
                self.axpy_col(j, wj, &mut out);
            }
        }
        out
    }

    /// Transposed matvec `X^T u`.
    pub fn matvec_t(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.n_rows);
        (0..self.n_cols).map(|j| self.dot_col(j, u)).collect()
    }

    /// Gather columns `js` into a dense column-major panel (n x B) of f32
    /// — the staging step for the DenseBlockHlo propose backend.
    /// `panel` must have length `n_rows * js.len()` and is fully
    /// overwritten.
    pub fn gather_panel_f32(&self, js: &[usize], panel: &mut [f32]) {
        assert_eq!(panel.len(), self.n_rows * js.len());
        panel.fill(0.0);
        for (b, &j) in js.iter().enumerate() {
            let base = b * self.n_rows;
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                panel[base + i as usize] = v as f32;
            }
        }
    }

    /// Dense representation (tests only; O(n*k) memory).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.n_cols]; self.n_rows];
        for j in 0..self.n_cols {
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                d[i as usize][j] = v;
            }
        }
        d
    }

    /// Internal accessors for sibling modules (io, csr conversion).
    pub(crate) fn parts(&self) -> (&[usize], &[u32], &[f64]) {
        (&self.col_ptr, &self.row_idx, &self.values)
    }
}

#[cfg(test)]
pub(crate) fn small_fixture() -> CscMatrix {
    // 4x3:
    //   [1 0 2]
    //   [0 3 0]
    //   [4 0 0]
    //   [0 5 6]
    CscMatrix::from_parts(
        4,
        3,
        vec![0, 2, 4, 6],
        vec![0, 2, 1, 3, 0, 3],
        vec![1.0, 4.0, 3.0, 5.0, 2.0, 6.0],
    )
    .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_parts_validates() {
        assert!(CscMatrix::from_parts(2, 1, vec![0, 1], vec![5], vec![1.0]).is_err());
        assert!(CscMatrix::from_parts(2, 1, vec![0], vec![], vec![]).is_err());
        assert!(
            CscMatrix::from_parts(2, 1, vec![0, 2], vec![1, 0], vec![1.0, 1.0]).is_err(),
            "unsorted rows must be rejected"
        );
        assert!(
            CscMatrix::from_parts(2, 1, vec![0, 2], vec![0, 0], vec![1.0, 1.0]).is_err(),
            "duplicate rows must be rejected"
        );
    }

    #[test]
    fn col_access() {
        let m = small_fixture();
        assert_eq!(m.n_rows(), 4);
        assert_eq!(m.n_cols(), 3);
        assert_eq!(m.nnz(), 6);
        let (rows, vals) = m.col(1);
        assert_eq!(rows, &[1, 3]);
        assert_eq!(vals, &[3.0, 5.0]);
        assert_eq!(m.col_nnz(2), 2);
        assert_eq!(m.mean_col_nnz(), 2.0);
    }

    #[test]
    fn dot_and_axpy() {
        let m = small_fixture();
        let d = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.dot_col(0, &d), 1.0 + 12.0);
        let mut y = [0.0; 4];
        m.axpy_col(2, 2.0, &mut y);
        assert_eq!(y, [4.0, 0.0, 0.0, 12.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = small_fixture();
        let w = [1.0, -1.0, 0.5];
        let got = m.matvec(&w);
        let dense = m.to_dense();
        for i in 0..4 {
            let want: f64 = (0..3).map(|j| dense[i][j] * w[j]).sum();
            assert!((got[i] - want).abs() < 1e-12);
        }
        let u = [1.0, 2.0, 3.0, 4.0];
        let got_t = m.matvec_t(&u);
        for j in 0..3 {
            let want: f64 = (0..4).map(|i| dense[i][j] * u[i]).sum();
            assert!((got_t[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn normalize_columns_unit_norm() {
        let mut m = small_fixture();
        let norms = m.normalize_columns();
        assert!((norms[0] - (17f64).sqrt()).abs() < 1e-12);
        for (j, _) in norms.iter().enumerate() {
            let (_, vals) = m.col(j);
            let n: f64 = vals.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn gather_panel() {
        let m = small_fixture();
        let mut panel = vec![9.0f32; 8];
        m.gather_panel_f32(&[2, 0], &mut panel);
        assert_eq!(panel, vec![2.0, 0.0, 0.0, 6.0, 1.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn col_sq_norms_match() {
        let m = small_fixture();
        assert_eq!(m.col_sq_norms(), vec![17.0, 34.0, 40.0]);
    }
}
