//! Sparse matrix substrate.
//!
//! Coordinate descent traverses *columns* of the design matrix `X`
//! (one column per proposal — the paper's definition of CD), so the
//! primary storage is CSC ([`CscMatrix`]). The coloring preprocessing
//! (Appendix A) and the spectral-radius matvec also need fast row
//! access, provided by the pattern-only [`RowPattern`] / value-carrying
//! [`CsrMatrix`].

pub mod coo;
pub mod csc;
pub mod csr;
pub mod io;
pub mod ops;

pub use coo::CooBuilder;
pub use csc::CscMatrix;
pub use csr::{CsrMatrix, RowPattern};
