//! Coordinate-format builder: the ergonomic way to construct a
//! [`CscMatrix`] from generators and file loaders. Accumulates (row, col,
//! value) triplets, then sorts/deduplicates into CSC.

use super::csc::CscMatrix;

/// Triplet accumulator. Duplicate (row, col) entries are summed on
/// [`CooBuilder::build`], matching the usual COO->CSC convention.
#[derive(Clone, Debug, Default)]
pub struct CooBuilder {
    n_rows: usize,
    n_cols: usize,
    entries: Vec<(u32, u32, f64)>, // (col, row, value) for cheap col sort
}

impl CooBuilder {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        assert!(n_rows < u32::MAX as usize && n_cols < u32::MAX as usize);
        Self {
            n_rows,
            n_cols,
            entries: Vec::new(),
        }
    }

    /// Preallocate for `nnz` entries.
    pub fn with_capacity(n_rows: usize, n_cols: usize, nnz: usize) -> Self {
        let mut b = Self::new(n_rows, n_cols);
        b.entries.reserve(nnz);
        b
    }

    /// Add one entry. Panics on out-of-bounds indices.
    #[inline]
    pub fn push(&mut self, row: usize, col: usize, value: f64) {
        assert!(row < self.n_rows, "row {row} >= {}", self.n_rows);
        assert!(col < self.n_cols, "col {col} >= {}", self.n_cols);
        self.entries.push((col as u32, row as u32, value));
    }

    /// Number of (possibly duplicate) triplets so far.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Sort, merge duplicates (summing), drop explicit zeros, build CSC.
    pub fn build(mut self) -> CscMatrix {
        self.entries.sort_unstable_by_key(|&(c, r, _)| (c, r));

        let mut col_ptr = vec![0usize; self.n_cols + 1];
        let mut row_idx = Vec::with_capacity(self.entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(self.entries.len());

        let mut it = self.entries.iter().peekable();
        while let Some(&(c, r, v)) = it.next() {
            let mut acc = v;
            while let Some(&&(c2, r2, v2)) = it.peek() {
                if c2 == c && r2 == r {
                    acc += v2;
                    it.next();
                } else {
                    break;
                }
            }
            if acc != 0.0 {
                row_idx.push(r);
                values.push(acc);
                col_ptr[c as usize + 1] += 1;
            }
        }
        for j in 0..self.n_cols {
            col_ptr[j + 1] += col_ptr[j];
        }
        CscMatrix::from_parts(self.n_rows, self.n_cols, col_ptr, row_idx, values)
            .expect("CooBuilder produced invalid CSC (internal bug)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_sorted_csc() {
        let mut b = CooBuilder::new(3, 2);
        b.push(2, 1, 5.0);
        b.push(0, 0, 1.0);
        b.push(1, 1, 2.0);
        b.push(2, 0, 3.0);
        let m = b.build();
        assert_eq!(m.col(0), (&[0u32, 2][..], &[1.0, 3.0][..]));
        assert_eq!(m.col(1), (&[1u32, 2][..], &[2.0, 5.0][..]));
    }

    #[test]
    fn duplicates_sum_and_zeros_drop() {
        let mut b = CooBuilder::new(2, 1);
        b.push(0, 0, 1.5);
        b.push(0, 0, 2.5);
        b.push(1, 0, 3.0);
        b.push(1, 0, -3.0); // cancels to zero -> dropped
        let m = b.build();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.col(0), (&[0u32][..], &[4.0][..]));
    }

    #[test]
    #[should_panic(expected = "row 5")]
    fn bounds_checked() {
        let mut b = CooBuilder::new(3, 2);
        b.push(5, 0, 1.0);
    }

    #[test]
    fn empty_build() {
        let m = CooBuilder::new(4, 3).build();
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.n_rows(), 4);
        assert_eq!(m.n_cols(), 3);
    }
}
