//! Row-oriented views of the design matrix.
//!
//! [`RowPattern`] is the *pattern-only* transpose (no values): it is what
//! the coloring preprocessing (Appendix A) walks — "features sharing a
//! sample" is exactly "columns adjacent through a row". [`CsrMatrix`]
//! carries values too, for row-oriented numerics.

use super::csc::CscMatrix;

/// Pattern-only CSR: for each row, the sorted column indices with a
/// nonzero in that row.
#[derive(Clone, Debug)]
pub struct RowPattern {
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    n_cols: usize,
}

impl RowPattern {
    /// Build from a CSC matrix by bucket-counting (O(nnz)).
    pub fn from_csc(m: &CscMatrix) -> Self {
        let (col_ptr, row_idx, _) = m.parts();
        let n_rows = m.n_rows();
        let mut row_ptr = vec![0usize; n_rows + 1];
        for &r in row_idx {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..n_rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut col_idx = vec![0u32; row_idx.len()];
        let mut cursor = row_ptr.clone();
        for j in 0..m.n_cols() {
            for &r in &row_idx[col_ptr[j]..col_ptr[j + 1]] {
                col_idx[cursor[r as usize]] = j as u32;
                cursor[r as usize] += 1;
            }
        }
        // columns visited in increasing j, so each row is already sorted
        Self {
            row_ptr,
            col_idx,
            n_cols: m.n_cols(),
        }
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        self.row_ptr.len() - 1
    }

    #[inline]
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Columns with support on row i.
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// nnz of row i (the row "degree" in the bipartite graph).
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    /// Maximum row degree (bounds the number of colors needed).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.n_rows()).map(|i| self.row_nnz(i)).max().unwrap_or(0)
    }
}

/// Value-carrying CSR.
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    values: Vec<f64>,
    n_cols: usize,
}

impl CsrMatrix {
    pub fn from_csc(m: &CscMatrix) -> Self {
        let (col_ptr, row_idx, vals) = m.parts();
        let n_rows = m.n_rows();
        let mut row_ptr = vec![0usize; n_rows + 1];
        for &r in row_idx {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..n_rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut col_idx = vec![0u32; row_idx.len()];
        let mut values = vec![0.0; row_idx.len()];
        let mut cursor = row_ptr.clone();
        for j in 0..m.n_cols() {
            for (&r, &v) in row_idx[col_ptr[j]..col_ptr[j + 1]]
                .iter()
                .zip(&vals[col_ptr[j]..col_ptr[j + 1]])
            {
                let c = cursor[r as usize];
                col_idx[c] = j as u32;
                values[c] = v;
                cursor[r as usize] += 1;
            }
        }
        Self {
            row_ptr,
            col_idx,
            values,
            n_cols: m.n_cols(),
        }
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        self.row_ptr.len() - 1
    }

    #[inline]
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Entries of row i as (cols, values) parallel slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let r = self.row_ptr[i]..self.row_ptr[i + 1];
        (&self.col_idx[r.clone()], &self.values[r])
    }

    /// Row dot product <x_i, w>.
    #[inline]
    pub fn dot_row(&self, i: usize, w: &[f64]) -> f64 {
        let (cols, vals) = self.row(i);
        cols.iter()
            .zip(vals)
            .map(|(&j, &v)| v * w[j as usize])
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csc::small_fixture;

    #[test]
    fn row_pattern_roundtrip() {
        let m = small_fixture();
        let p = RowPattern::from_csc(&m);
        assert_eq!(p.n_rows(), 4);
        assert_eq!(p.n_cols(), 3);
        assert_eq!(p.row(0), &[0, 2]);
        assert_eq!(p.row(1), &[1]);
        assert_eq!(p.row(2), &[0]);
        assert_eq!(p.row(3), &[1, 2]);
        assert_eq!(p.max_row_nnz(), 2);
    }

    #[test]
    fn csr_matches_dense() {
        let m = small_fixture();
        let r = CsrMatrix::from_csc(&m);
        let dense = m.to_dense();
        for i in 0..4 {
            let (cols, vals) = r.row(i);
            let mut rowv = vec![0.0; 3];
            for (&j, &v) in cols.iter().zip(vals) {
                rowv[j as usize] = v;
            }
            assert_eq!(rowv, dense[i]);
        }
        let w = [1.0, 2.0, 3.0];
        for i in 0..4 {
            let want: f64 = (0..3).map(|j| dense[i][j] * w[j]).sum();
            assert!((r.dot_row(i, &w) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn rows_sorted() {
        let m = small_fixture();
        let p = RowPattern::from_csc(&m);
        for i in 0..p.n_rows() {
            let row = p.row(i);
            assert!(row.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
