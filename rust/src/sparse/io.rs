//! Dataset I/O: LIBSVM text format (the lingua franca for DOROTHEA /
//! RCV1-style problems) and a fast binary snapshot format so generated
//! synthetic datasets can be cached across runs.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::coo::CooBuilder;
use super::csc::CscMatrix;

/// A supervised sparse dataset: design matrix + labels (+-1 for
/// classification, arbitrary reals for regression).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: CscMatrix,
    pub y: Vec<f64>,
    pub name: String,
}

impl Dataset {
    pub fn n_samples(&self) -> usize {
        self.x.n_rows()
    }

    pub fn n_features(&self) -> usize {
        self.x.n_cols()
    }
}

/// Parse LIBSVM text: `label idx:val idx:val ...` per line, 1-based
/// indices. `n_features` of `None` infers the dimension from the data.
pub fn read_libsvm(reader: impl Read, n_features: Option<usize>) -> Result<Dataset> {
    let mut labels = Vec::new();
    let mut trips: Vec<(usize, usize, f64)> = Vec::new();
    let mut max_feat = 0usize;

    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let row = labels.len();
        labels.push(label);
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("line {}: token '{tok}'", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("line {}: index '{idx}'", lineno + 1))?;
            anyhow::ensure!(idx >= 1, "line {}: libsvm indices are 1-based", lineno + 1);
            let val: f64 = val
                .parse()
                .with_context(|| format!("line {}: value '{val}'", lineno + 1))?;
            max_feat = max_feat.max(idx);
            trips.push((row, idx - 1, val));
        }
    }

    let k = match n_features {
        Some(k) => {
            anyhow::ensure!(max_feat <= k, "feature index {max_feat} > declared {k}");
            k
        }
        None => max_feat,
    };
    let mut b = CooBuilder::with_capacity(labels.len(), k, trips.len());
    for (r, c, v) in trips {
        b.push(r, c, v);
    }
    Ok(Dataset {
        x: b.build(),
        y: labels,
        name: "libsvm".into(),
    })
}

/// Write LIBSVM text (1-based indices, row-major).
pub fn write_libsvm(ds: &Dataset, writer: impl Write) -> Result<()> {
    let mut w = BufWriter::new(writer);
    let csr = super::csr::CsrMatrix::from_csc(&ds.x);
    for i in 0..ds.n_samples() {
        write!(w, "{}", ds.y[i])?;
        let (cols, vals) = csr.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            write!(w, " {}:{}", j + 1, v)?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"GENCDDS1";

/// Binary snapshot: magic, dims, col_ptr, row_idx, values, labels — all
/// little-endian. ~8x faster to load than libsvm text for REUTERS scale.
pub fn write_binary(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    let (col_ptr, row_idx, values) = ds.x.parts();
    for dim in [ds.x.n_rows() as u64, ds.x.n_cols() as u64, ds.x.nnz() as u64] {
        w.write_all(&dim.to_le_bytes())?;
    }
    for &p in col_ptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &r in row_idx {
        w.write_all(&r.to_le_bytes())?;
    }
    for &v in values {
        w.write_all(&v.to_le_bytes())?;
    }
    for &v in &ds.y {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Load a [`write_binary`] snapshot.
pub fn read_binary(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == BIN_MAGIC, "bad magic in {}", path.display());

    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut dyn Read| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let n_rows = read_u64(&mut r)? as usize;
    let n_cols = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;

    let mut col_ptr = Vec::with_capacity(n_cols + 1);
    for _ in 0..=n_cols {
        col_ptr.push(read_u64(&mut r)? as usize);
    }
    let mut row_idx = vec![0u32; nnz];
    {
        let mut buf = vec![0u8; nnz * 4];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            row_idx[i] = u32::from_le_bytes(c.try_into().unwrap());
        }
    }
    let read_f64s = |r: &mut dyn Read, len: usize| -> Result<Vec<f64>> {
        let mut buf = vec![0u8; len * 8];
        r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    };
    let values = read_f64s(&mut r, nnz)?;
    let y = read_f64s(&mut r, n_rows)?;

    Ok(Dataset {
        x: CscMatrix::from_parts(n_rows, n_cols, col_ptr, row_idx, values)?,
        y,
        name: path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "binary".into()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Dataset {
        let mut b = CooBuilder::new(3, 4);
        b.push(0, 0, 1.0);
        b.push(0, 3, -2.5);
        b.push(1, 1, 0.5);
        b.push(2, 0, 3.0);
        b.push(2, 2, 4.0);
        Dataset {
            x: b.build(),
            y: vec![1.0, -1.0, 1.0],
            name: "fixture".into(),
        }
    }

    #[test]
    fn libsvm_roundtrip() {
        let ds = fixture();
        let mut buf = Vec::new();
        write_libsvm(&ds, &mut buf).unwrap();
        let back = read_libsvm(&buf[..], Some(4)).unwrap();
        assert_eq!(back.x, ds.x);
        assert_eq!(back.y, ds.y);
    }

    #[test]
    fn libsvm_parses_comments_and_blank_lines() {
        let text = "# header\n1 1:2.0 3:1.5\n\n-1 2:0.25 # trailing\n";
        let ds = read_libsvm(text.as_bytes(), None).unwrap();
        assert_eq!(ds.n_samples(), 2);
        assert_eq!(ds.n_features(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.col(0), (&[0u32][..], &[2.0][..]));
        assert_eq!(ds.x.col(1), (&[1u32][..], &[0.25][..]));
    }

    #[test]
    fn libsvm_rejects_zero_based() {
        let text = "1 0:2.0\n";
        assert!(read_libsvm(text.as_bytes(), None).is_err());
    }

    #[test]
    fn binary_roundtrip() {
        let ds = fixture();
        let dir = std::env::temp_dir().join("gencd_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fixture.bin");
        write_binary(&ds, &path).unwrap();
        let back = read_binary(&path).unwrap();
        assert_eq!(back.x, ds.x);
        assert_eq!(back.y, ds.y);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn binary_rejects_garbage() {
        let dir = std::env::temp_dir().join("gencd_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
