//! Trained-model persistence: sparse text format (feature index +
//! weight per line) so models are diffable, plus load for `gencd
//! predict`.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};

/// Write nonzero weights as `# gencd-model <k>` header + `j w` lines.
pub fn write_model(w: &[f64], writer: impl Write) -> anyhow::Result<()> {
    let mut out = BufWriter::new(writer);
    writeln!(out, "# gencd-model {}", w.len())?;
    for (j, &wj) in w.iter().enumerate() {
        if wj != 0.0 {
            writeln!(out, "{j} {wj}")?;
        }
    }
    out.flush()?;
    Ok(())
}

/// Read a [`write_model`] file back into a dense weight vector.
pub fn read_model(reader: impl Read) -> anyhow::Result<Vec<f64>> {
    let mut lines = BufReader::new(reader).lines();
    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty model file"))??;
    let k: usize = header
        .strip_prefix("# gencd-model ")
        .ok_or_else(|| anyhow::anyhow!("bad model header '{header}'"))?
        .trim()
        .parse()?;
    let mut w = vec![0.0; k];
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (j, v) = line
            .split_once(' ')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected 'j w'", lineno + 2))?;
        let j: usize = j.parse()?;
        anyhow::ensure!(j < k, "line {}: index {j} >= {k}", lineno + 2);
        w[j] = v.parse()?;
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut w = vec![0.0; 100];
        w[3] = 1.5;
        w[97] = -0.25;
        let mut buf = Vec::new();
        write_model(&w, &mut buf).unwrap();
        let back = read_model(&buf[..]).unwrap();
        assert_eq!(back, w);
        // sparse: only 3 lines (header + 2 weights)
        assert_eq!(String::from_utf8(buf).unwrap().lines().count(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_model(&b"nope"[..]).is_err());
        assert!(read_model(&b"# gencd-model 2\n5 1.0\n"[..]).is_err());
        assert!(read_model(&b""[..]).is_err());
    }
}
