//! Model evaluation: train/test splitting and classification metrics
//! for the trained l1 models (accuracy, precision/recall/F1, AUC) —
//! what a downstream user of the solver actually reports.

pub mod model_io;

use crate::sparse::io::Dataset;
use crate::sparse::{CooBuilder, CscMatrix};
use crate::util::Pcg64;

/// Split a dataset into train/test by sampling rows without
/// replacement. Column count is preserved in both halves.
pub fn train_test_split(ds: &Dataset, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..1.0).contains(&test_frac));
    let n = ds.n_samples();
    let n_test = ((n as f64) * test_frac).round() as usize;
    let mut rng = Pcg64::new(seed, 0x5B117);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let test_set: std::collections::HashSet<usize> =
        idx[..n_test].iter().copied().collect();

    // map old row -> new row per half
    let mut train_map = vec![usize::MAX; n];
    let mut test_map = vec![usize::MAX; n];
    let (mut tr, mut te) = (0usize, 0usize);
    for i in 0..n {
        if test_set.contains(&i) {
            test_map[i] = te;
            te += 1;
        } else {
            train_map[i] = tr;
            tr += 1;
        }
    }

    let mut btr = CooBuilder::new(tr, ds.n_features());
    let mut bte = CooBuilder::new(te, ds.n_features());
    for j in 0..ds.n_features() {
        let (rows, vals) = ds.x.col(j);
        for (&i, &v) in rows.iter().zip(vals) {
            let i = i as usize;
            if test_set.contains(&i) {
                bte.push(test_map[i], j, v);
            } else {
                btr.push(train_map[i], j, v);
            }
        }
    }
    let mut y_tr = vec![0.0; tr];
    let mut y_te = vec![0.0; te];
    for i in 0..n {
        if test_set.contains(&i) {
            y_te[test_map[i]] = ds.y[i];
        } else {
            y_tr[train_map[i]] = ds.y[i];
        }
    }
    (
        Dataset {
            x: btr.build(),
            y: y_tr,
            name: format!("{}-train", ds.name),
        },
        Dataset {
            x: bte.build(),
            y: y_te,
            name: format!("{}-test", ds.name),
        },
    )
}

/// Decision scores `X w` for a weight vector.
pub fn scores(x: &CscMatrix, w: &[f64]) -> Vec<f64> {
    x.matvec(w)
}

/// Binary classification metrics from +-1 labels and real scores.
#[derive(Clone, Copy, Debug)]
pub struct Metrics {
    pub accuracy: f64,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub auc: f64,
    pub n: usize,
}

/// Compute metrics (sign thresholding at 0; AUC via rank statistic).
pub fn classification_metrics(y: &[f64], scores: &[f64]) -> Metrics {
    assert_eq!(y.len(), scores.len());
    let n = y.len();
    let (mut tp, mut fp, mut tn, mut fne) = (0usize, 0usize, 0usize, 0usize);
    for (&yi, &s) in y.iter().zip(scores) {
        let pred_pos = s > 0.0;
        let is_pos = yi > 0.0;
        match (is_pos, pred_pos) {
            (true, true) => tp += 1,
            (false, true) => fp += 1,
            (false, false) => tn += 1,
            (true, false) => fne += 1,
        }
    }
    let safe = |num: f64, den: f64| if den > 0.0 { num / den } else { 0.0 };
    let accuracy = safe((tp + tn) as f64, n as f64);
    let precision = safe(tp as f64, (tp + fp) as f64);
    let recall = safe(tp as f64, (tp + fne) as f64);
    let f1 = safe(2.0 * precision * recall, precision + recall);
    Metrics {
        accuracy,
        precision,
        recall,
        f1,
        auc: auc(y, scores),
        n,
    }
}

/// AUC = P(score_pos > score_neg), ties counted half (Mann-Whitney U
/// from midranks).
pub fn auc(y: &[f64], scores: &[f64]) -> f64 {
    let n = y.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    // midranks over tie groups
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for &o in &order[i..=j] {
            ranks[o] = mid;
        }
        i = j + 1;
    }
    let n_pos = y.iter().filter(|&&v| v > 0.0).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let rank_sum_pos: f64 = y
        .iter()
        .zip(&ranks)
        .filter(|(&yi, _)| yi > 0.0)
        .map(|(_, &r)| r)
        .sum();
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{dorothea_like, GenOptions};

    #[test]
    fn split_preserves_everything() {
        let ds = dorothea_like(&GenOptions {
            scale: 0.03,
            ..Default::default()
        });
        let (tr, te) = train_test_split(&ds, 0.25, 1);
        assert_eq!(tr.n_samples() + te.n_samples(), ds.n_samples());
        assert_eq!(te.n_samples(), (ds.n_samples() as f64 * 0.25).round() as usize);
        assert_eq!(tr.n_features(), ds.n_features());
        assert_eq!(te.n_features(), ds.n_features());
        assert_eq!(tr.x.nnz() + te.x.nnz(), ds.x.nnz());
        // label counts preserved
        let pos = |d: &Dataset| d.y.iter().filter(|&&v| v > 0.0).count();
        assert_eq!(pos(&tr) + pos(&te), pos(&ds));
    }

    #[test]
    fn split_deterministic() {
        let ds = dorothea_like(&GenOptions {
            scale: 0.02,
            ..Default::default()
        });
        let (a, _) = train_test_split(&ds, 0.3, 9);
        let (b, _) = train_test_split(&ds, 0.3, 9);
        assert_eq!(a.x, b.x);
        let (c, _) = train_test_split(&ds, 0.3, 10);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn metrics_perfect_classifier() {
        let y = vec![1.0, 1.0, -1.0, -1.0];
        let s = vec![2.0, 0.5, -0.5, -2.0];
        let m = classification_metrics(&y, &s);
        assert_eq!(m.accuracy, 1.0);
        assert_eq!(m.precision, 1.0);
        assert_eq!(m.recall, 1.0);
        assert_eq!(m.f1, 1.0);
        assert_eq!(m.auc, 1.0);
    }

    #[test]
    fn metrics_inverted_classifier() {
        let y = vec![1.0, 1.0, -1.0, -1.0];
        let s = vec![-2.0, -0.5, 0.5, 2.0];
        let m = classification_metrics(&y, &s);
        assert_eq!(m.accuracy, 0.0);
        assert_eq!(m.auc, 0.0);
    }

    #[test]
    fn auc_handles_ties_and_degenerate() {
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let s = vec![0.0, 0.0, 0.0, 0.0];
        assert!((auc(&y, &s) - 0.5).abs() < 1e-12);
        assert_eq!(auc(&[1.0, 1.0], &[0.1, 0.2]), 0.5); // one class only
    }

    #[test]
    fn auc_matches_pair_enumeration() {
        let mut rng = crate::util::Pcg64::seeded(3);
        let n = 50;
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < 0.4 { 1.0 } else { -1.0 })
            .collect();
        let s: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let got = auc(&y, &s);
        // brute force
        let (mut wins, mut total) = (0.0f64, 0.0f64);
        for i in 0..n {
            for j in 0..n {
                if y[i] > 0.0 && y[j] < 0.0 {
                    total += 1.0;
                    if s[i] > s[j] {
                        wins += 1.0;
                    } else if s[i] == s[j] {
                        wins += 0.5;
                    }
                }
            }
        }
        assert!((got - wins / total).abs() < 1e-12, "{got} vs {}", wins / total);
    }

    #[test]
    fn trained_model_beats_chance_on_heldout() {
        // the whole point: train on train, evaluate on test.
        // reuters twin: ~45% positive, so a 30% split is never one-class
        let mut ds = crate::data::reuters_like(&GenOptions {
            scale: 0.03,
            ..Default::default()
        });
        ds.x.normalize_columns();
        let (train, test) = train_test_split(&ds, 0.3, 5);
        let mut cfg = crate::config::RunConfig::default();
        cfg.dataset.normalize = false; // already normalized
        cfg.problem.lam = 1e-4;
        cfg.solver.algorithm = "thread-greedy".into();
        cfg.solver.threads = 2;
        cfg.solver.max_seconds = 4.0;
        cfg.solver.line_search_steps = 10;
        let res = crate::coordinator::driver::run_on(&cfg, train, None).unwrap();
        let s = scores(&test.x, &res.w);
        let m = classification_metrics(&test.y, &s);
        assert!(m.auc > 0.7, "test AUC {} (metrics {m:?})", m.auc);
    }
}
