//! Integration: the PJRT runtime executing the AOT artifacts, validated
//! against the pure-Rust sparse propose path. Skips (with a notice) when
//! `make artifacts` hasn't been run.

use std::sync::atomic::Ordering::Relaxed;

use gencd::config::RunConfig;
use gencd::coordinator::engine::{self, BlockProposer, EngineConfig};
use gencd::coordinator::problem::{Problem, SharedState};
use gencd::coordinator::propose;
use gencd::coordinator::accept;
use gencd::coordinator::select::RandomSubset;
use gencd::data::{dorothea_like, GenOptions};
use gencd::loss::Logistic;
use gencd::runtime::{HloObjective, HloProposer, Manifest, Runtime};
use gencd::util::Pcg64;

fn artifacts_available() -> bool {
    let ok = Manifest::default_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping HLO runtime test: run `make artifacts` first");
    }
    ok
}

/// A dorothea-twin problem small enough for the n=1024 artifact.
fn problem() -> Problem {
    let mut ds = dorothea_like(&GenOptions {
        scale: 0.05, // n = 40, k = 5000
        ..Default::default()
    });
    ds.x.normalize_columns();
    Problem::new(ds, Box::new(Logistic), 1e-4)
}

#[test]
fn hlo_propose_matches_sparse_path() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::from_default_dir().expect("runtime");
    let p = problem();
    let mut hlo = HloProposer::new(&rt, &p).expect("proposer");

    // random warm start so gradients are nontrivial
    let mut rng = Pcg64::seeded(42);
    let w0: Vec<f64> = (0..p.n_features())
        .map(|j| if j % 97 == 0 { rng.range_f64(-0.5, 0.5) } else { 0.0 })
        .collect();
    let state = SharedState::from_warm_start(&p, &w0);
    propose::refresh_dloss(&p, &state, 0, p.n_samples());

    // a mixed selection: dense-ish and empty columns
    let selected: Vec<u32> = (0..200u32).step_by(3).collect();
    hlo.propose_block(&p, &state, &selected).expect("propose");

    for &j in &selected {
        let sparse = propose::propose(&p, &state, j as usize, true);
        let d_hlo = state.delta[j as usize].load(Relaxed);
        let phi_hlo = state.phi[j as usize].load(Relaxed);
        assert!(
            (sparse.delta - d_hlo).abs() < 1e-4 * (1.0 + sparse.delta.abs()),
            "j={j}: delta sparse {} vs hlo {}",
            sparse.delta,
            d_hlo
        );
        assert!(
            (sparse.phi - phi_hlo).abs() < 1e-4 * (1.0 + sparse.phi.abs()),
            "j={j}: phi sparse {} vs hlo {}",
            sparse.phi,
            phi_hlo
        );
    }
}

#[test]
fn hlo_objective_matches_rust() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::from_default_dir().expect("runtime");
    let p = problem();
    let mut obj = HloObjective::new(&rt, &p).expect("objective");

    let mut rng = Pcg64::seeded(7);
    let z: Vec<f64> = (0..p.n_samples()).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let f_hlo = obj.smooth(&z).expect("smooth");
    let f_rust = gencd::loss::smooth_part(p.loss.as_ref(), &p.y, &z);
    assert!(
        (f_hlo - f_rust).abs() < 1e-5 * (1.0 + f_rust.abs()),
        "hlo {f_hlo} vs rust {f_rust}"
    );
}

#[test]
fn full_solve_with_hlo_backend_descends() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::from_default_dir().expect("runtime");
    let p = problem();
    let mut hlo = HloProposer::new(&rt, &p).expect("proposer");

    let sel = RandomSubset {
        rng: Pcg64::seeded(3),
        k: p.n_features(),
        size: 32,
    };
    let cfg = EngineConfig {
        threads: 1,
        max_iters: 25,
        max_seconds: 60.0,
        ..Default::default()
    };
    let state = SharedState::new(p.n_samples(), p.n_features());
    let out = engine::solve_from(
        &p,
        &state,
        Box::new(sel),
        accept::all(),
        &cfg,
        engine::EngineHooks::with_block_proposer(&mut hlo),
    );
    let first = out.history.records.first().unwrap().objective;
    assert!(
        out.objective < first,
        "objective {first} -> {} (should descend)",
        out.objective
    );
    assert!(hlo.calls > 0, "proposer never invoked");
}

#[test]
fn driver_rejects_hlo_without_proposer() {
    let mut cfg = RunConfig::default();
    cfg.dataset.name = "dorothea@0.02".into();
    cfg.solver.backend = gencd::config::Backend::DenseBlockHlo;
    assert!(gencd::coordinator::driver::run(&cfg).is_err());
}
