//! Acceptance tests for the typed event stream (`gencd::event`): the
//! observability layer must be **semantically transparent** — attaching
//! a subscriber cannot change what the solver computes. For every
//! `Algorithm` preset, single- and multi-threaded, pooled and sharded,
//! the same solve run three ways — no subscriber, the statically-free
//! `NoopSubscriber`, and a live `MetricsAggregator` — must land on the
//! bitwise-identical iterate. (The companion contract, byte-identical
//! `StructuredLog` replay under fault injection, lives in
//! `rust/tests/sim_faults.rs`.)

use gencd::coordinator::engine::UpdatePath;
use gencd::data::{reuters_like, GenOptions};
use gencd::event::{MetricsAggregator, NoopSubscriber, StructuredLog};
use gencd::sparse::io::Dataset;
use gencd::Solver;

/// All eight (Select, Accept) presets, by their registry names.
const PRESETS: [&str; 8] = [
    "ccd",
    "scd",
    "shotgun",
    "thread-greedy",
    "greedy",
    "coloring",
    "topk",
    "block-shotgun",
];

fn dataset() -> Dataset {
    let mut ds = reuters_like(&GenOptions::with_scale(0.01));
    ds.x.normalize_columns();
    ds
}

enum Sub {
    None,
    Noop,
    Metrics(MetricsAggregator),
}

/// One deterministic solve: fixed iteration budget, per-iteration log
/// cadence (wall-clock cadence would make the tol/log schedule — and
/// with it nothing else, which is the point — nondeterministic), pinned
/// update path so no runtime auto-switching consults the clock.
fn solve_w(ds: &Dataset, alg: &str, threads: usize, shards: usize, sub: Sub) -> Vec<f64> {
    let b = Solver::builder()
        .matrix(ds.x.clone())
        .labels(ds.y.clone())
        .boxed_loss(gencd::loss::by_name("squared").unwrap())
        .lambda(1e-3)
        .algorithm(alg.parse().unwrap())
        .threads(threads)
        .shards(shards)
        .seed(11)
        .max_iters(12)
        .max_seconds(60.0)
        .log_every(1)
        .tol(0.0)
        .update_path(UpdatePath::Buffered);
    let b = match sub {
        Sub::None => b,
        Sub::Noop => b.subscriber(NoopSubscriber),
        Sub::Metrics(agg) => b.subscriber(agg),
    };
    let out = b.build().unwrap().solve();
    assert!(
        out.failure.is_none(),
        "{alg} T={threads} S={shards}: {:?}",
        out.failure
    );
    out.w
}

fn assert_bit_identical(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: w[{i}] differs");
    }
}

#[test]
fn subscribers_are_semantically_transparent_on_every_preset() {
    let ds = dataset();
    for alg in PRESETS {
        for (threads, shards) in [(1, 1), (4, 1), (1, 2), (4, 2)] {
            let tag = format!("{alg} T={threads} S={shards}");
            let base = solve_w(&ds, alg, threads, shards, Sub::None);
            let noop = solve_w(&ds, alg, threads, shards, Sub::Noop);
            assert_bit_identical(&base, &noop, &format!("{tag} (noop subscriber)"));
            let agg = MetricsAggregator::new();
            let metered = solve_w(&ds, alg, threads, shards, Sub::Metrics(agg.clone()));
            assert_bit_identical(&base, &metered, &format!("{tag} (metrics aggregator)"));
            let m = agg.snapshot();
            assert!(m.iterations > 0, "{tag}: aggregator saw no iterations");
        }
    }
}

#[test]
fn structured_log_covers_required_kinds_pooled() {
    // a pooled solve's json stream passes the same validation the CI
    // `events` job runs via `gencd events --check`
    let ds = dataset();
    let log = StructuredLog::json();
    let out = Solver::builder()
        .matrix(ds.x.clone())
        .labels(ds.y.clone())
        .boxed_loss(gencd::loss::by_name("squared").unwrap())
        .lambda(1e-3)
        .algorithm("shotgun".parse().unwrap())
        .threads(2)
        .seed(5)
        .max_iters(10)
        .max_seconds(60.0)
        .log_every(1)
        .tol(0.0)
        .subscriber(log.clone())
        .build()
        .unwrap()
        .solve();
    assert!(out.failure.is_none());
    let lines = log.lines();
    assert!(!lines.is_empty());
    let report =
        gencd::event::check::check_lines(lines.iter().map(|s| s.as_str())).expect("valid json");
    gencd::event::check::verify_coverage(&report).expect("expected kinds present");
}

#[test]
fn sharded_structured_log_sees_the_reconcile_layer() {
    let ds = dataset();
    let log = StructuredLog::text();
    let out = Solver::builder()
        .matrix(ds.x.clone())
        .labels(ds.y.clone())
        .boxed_loss(gencd::loss::by_name("squared").unwrap())
        .lambda(1e-3)
        .algorithm("shotgun".parse().unwrap())
        .threads(2)
        .shards(2)
        .seed(5)
        .max_iters(10)
        .max_seconds(60.0)
        .log_every(1)
        .tol(0.0)
        .subscriber(log.clone())
        .build()
        .unwrap()
        .solve();
    assert!(out.failure.is_none());
    let lines = log.lines();
    assert!(
        lines.iter().any(|l| l.contains(" iteration ")),
        "sharded stream must carry iteration events: {lines:?}"
    );
    assert!(
        lines.iter().any(|l| l.contains(" reconcile ")),
        "sharded stream must carry reconcile events: {lines:?}"
    );
}
