//! Edge cases and failure injection: degenerate inputs, corrupt
//! artifacts, extreme parameters — the solver must fail cleanly or
//! behave sensibly, never hang or corrupt state.

use gencd::config::RunConfig;
use gencd::coordinator::accept::AcceptAll;
use gencd::coordinator::engine::{solve, EngineConfig};
use gencd::coordinator::problem::Problem;
use gencd::coordinator::select::Cyclic;
use gencd::coordinator::driver::run_on;
use gencd::loss::{Logistic, SmoothedHinge};
use gencd::sparse::io::Dataset;
use gencd::sparse::CooBuilder;
use gencd::util::Pcg64;

fn cfg(iters: usize) -> EngineConfig {
    EngineConfig {
        threads: 2,
        max_iters: iters,
        max_seconds: 10.0,
        ..Default::default()
    }
}

#[test]
fn empty_columns_are_inert() {
    // matrix with several all-zero columns: proposals there must be 0
    let mut b = CooBuilder::new(8, 6);
    for i in 0..8 {
        b.push(i, 0, 1.0);
        b.push(i, 3, (i as f64) - 3.5);
    }
    let x = b.build();
    let y: Vec<f64> = (0..8).map(|i| if i < 4 { 1.0 } else { -1.0 }).collect();
    let p = Problem::new(
        Dataset {
            x,
            y,
            name: "zeros".into(),
        },
        Box::new(Logistic),
        1e-3,
    );
    let sel = Cyclic {
        next: 0,
        k: p.n_features(),
    };
    let out = solve(&p, sel, AcceptAll, &cfg(60));
    for j in [1usize, 2, 4, 5] {
        assert_eq!(out.w[j], 0.0, "empty column {j} must stay zero");
    }
    assert!(out.objective.is_finite());
}

#[test]
fn single_sample_single_feature() {
    let mut b = CooBuilder::new(1, 1);
    b.push(0, 0, 1.0);
    let p = Problem::new(
        Dataset {
            x: b.build(),
            y: vec![1.0],
            name: "tiny".into(),
        },
        Box::new(Logistic),
        1e-4,
    );
    let sel = Cyclic { next: 0, k: 1 };
    let out = solve(&p, sel, AcceptAll, &cfg(200));
    assert!(out.w[0] > 0.0, "weight should move toward the label");
    assert!(out.objective < (2f64).ln());
}

#[test]
fn huge_lambda_keeps_everything_zero() {
    let ds = gencd::data::by_name("dorothea@0.02").unwrap();
    let mut rc = RunConfig::default();
    rc.dataset.name = "dorothea@0.02".into();
    rc.problem.lam = 1e6;
    rc.solver.algorithm = "shotgun".into();
    rc.solver.max_iters = 100;
    rc.solver.threads = 2;
    let res = run_on(&rc, ds, None).unwrap();
    assert_eq!(res.nnz, 0);
    assert_eq!(res.metrics.updates, 0);
}

#[test]
fn extreme_labels_stay_finite() {
    // y values far outside {-1, +1} with squared loss: large gradients,
    // but conservative steps must keep everything finite
    let mut b = CooBuilder::new(4, 3);
    let mut rng = Pcg64::seeded(1);
    for j in 0..3 {
        for i in 0..4 {
            b.push(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let p = Problem::new(
        Dataset {
            x,
            y: vec![1e8, -1e8, 1e8, -1e8],
            name: "extreme".into(),
        },
        Box::new(gencd::loss::Squared),
        1e-3,
    );
    let sel = Cyclic { next: 0, k: 3 };
    let out = solve(&p, sel, AcceptAll, &cfg(300));
    assert!(out.objective.is_finite());
    assert!(out.w.iter().all(|w| w.is_finite()));
}

#[test]
fn smoothed_hinge_extension_trains() {
    // the non-paper loss exercises the Loss trait genericity end to end
    let ds = gencd::data::by_name("reuters@0.02").unwrap();
    let mut rc = RunConfig::default();
    rc.dataset.name = "reuters@0.02".into();
    rc.problem.loss = "smoothed_hinge".into();
    rc.problem.lam = 1e-4;
    rc.solver.algorithm = "thread-greedy".into();
    rc.solver.threads = 2;
    rc.solver.max_seconds = 3.0;
    let res = run_on(&rc, ds, None).unwrap();
    let first = res.history.records.first().unwrap().objective;
    assert!(res.objective < first * 0.8, "{first} -> {}", res.objective);
    assert!(res.nnz > 0);
}

#[test]
fn corrupt_artifact_fails_cleanly() {
    let dir = std::env::temp_dir().join("gencd_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": 1, "scalars": ["lam", "beta", "inv_n"], "entries": [
            {"variant": "x", "kind": "propose", "loss": "logistic",
             "n": 1024, "b": 16, "file": "broken.hlo.txt",
             "inputs": ["x_panel","y","z","mask","w","scalars"],
             "input_shapes": [[1024,16],[1024],[1024],[1024],[16],[3]],
             "outputs": ["g","delta","phi"]}]}"#,
    )
    .unwrap();
    std::fs::write(dir.join("broken.hlo.txt"), "this is not HLO").unwrap();
    let rt = gencd::runtime::Runtime::new(&dir).expect("client still builds");
    let entry = rt.manifest.find("propose", "logistic", 100).unwrap().clone();
    let err = match rt.compile(&entry) {
        Ok(_) => panic!("compiling garbage HLO must fail"),
        Err(e) => e,
    };
    assert!(
        err.to_string().contains("broken.hlo.txt"),
        "error should name the file: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_with_wrong_scalars_rejected() {
    let dir = std::env::temp_dir().join("gencd_bad_scalars");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": 1, "scalars": ["beta", "lam", "inv_n"], "entries": []}"#,
    )
    .unwrap();
    assert!(gencd::runtime::Manifest::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn oversubscribed_threads_still_correct() {
    // way more threads than cores AND than selected coordinates
    let ds = gencd::data::by_name("dorothea@0.02").unwrap();
    let mut rc = RunConfig::default();
    rc.dataset.name = "dorothea@0.02".into();
    rc.problem.lam = 1e-4;
    rc.solver.algorithm = "scd".into(); // |J| = 1 << threads
    rc.solver.threads = 16;
    rc.solver.max_iters = 300;
    let res = run_on(&rc, ds, None).unwrap();
    let first = res.history.records.first().unwrap().objective;
    assert!(res.objective <= first);
    assert!(res.objective.is_finite());
}

#[test]
fn zero_second_budget_stops_immediately() {
    let ds = gencd::data::by_name("dorothea@0.02").unwrap();
    let mut rc = RunConfig::default();
    rc.dataset.name = "dorothea@0.02".into();
    rc.solver.max_seconds = 0.0;
    rc.solver.algorithm = "scd".into();
    let res = run_on(&rc, ds, None).unwrap();
    assert_eq!(res.metrics.iterations, 0);
    assert_eq!(
        res.stop,
        gencd::coordinator::convergence::StopReason::MaxSeconds
    );
}

#[test]
fn hinge_gamma_variants_all_descend() {
    for gamma in [0.25, 1.0, 4.0] {
        let mut b = CooBuilder::new(20, 10);
        let mut rng = Pcg64::seeded(7);
        for j in 0..10 {
            for i in 0..20 {
                if rng.next_f64() < 0.4 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let mut x = b.build();
        x.normalize_columns();
        let y: Vec<f64> = (0..20).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let p = Problem::new(
            Dataset {
                x,
                y,
                name: "hinge".into(),
            },
            Box::new(SmoothedHinge { gamma }),
            1e-4,
        );
        let sel = Cyclic { next: 0, k: 10 };
        let out = solve(&p, sel, AcceptAll, &cfg(200));
        let first = out.history.records.first().unwrap().objective;
        assert!(
            out.objective <= first,
            "gamma={gamma}: {first} -> {}",
            out.objective
        );
    }
}
