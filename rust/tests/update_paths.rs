//! Differential tests over the engine's three Update-phase disciplines
//! (atomic CAS, buffered scatter+reduce, conflict-free stores): all of
//! them must produce the same solution — bit-exact at T=1, within
//! floating-point reassociation noise under real 8-thread contention —
//! and must leave the incremental residual `z` consistent with `w`
//! (the `z_drift` invariant) after every run.

use gencd::coordinator::accept;
use gencd::coordinator::engine::{solve_from, EngineConfig, EngineHooks, SolveOutput, UpdatePath};
use gencd::coordinator::problem::{Problem, SharedState};
use gencd::coordinator::select::{Cyclic, RandomSubset, Select};
use gencd::loss::{Logistic, Squared};
use gencd::sparse::io::Dataset;
use gencd::sparse::CooBuilder;
use gencd::util::Pcg64;

/// Random sparse problem, normalized columns.
fn make_problem(seed: u64, n: usize, k: usize, logistic: bool) -> Problem {
    let mut rng = Pcg64::seeded(seed);
    let mut b = CooBuilder::new(n, k);
    for j in 0..k {
        for i in 0..n {
            if rng.next_f64() < 0.35 {
                b.push(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let wstar: Vec<f64> = (0..k).map(|j| if j < 4 { 1.0 } else { 0.0 }).collect();
    let scores = x.matvec(&wstar);
    let y: Vec<f64> = if logistic {
        scores.iter().map(|&s| if s > 0.0 { 1.0 } else { -1.0 }).collect()
    } else {
        scores
    };
    let loss: Box<dyn gencd::loss::Loss> =
        if logistic { Box::new(Logistic) } else { Box::new(Squared) };
    Problem::new(
        Dataset {
            x,
            y,
            name: "update-paths".into(),
        },
        loss,
        1e-3,
    )
}

/// Solve with a forced update path; returns (output, z drift).
/// `cyclic` selects one coordinate per iteration (CCD); otherwise a
/// seeded random subset of 6 (SHOTGUN-style).
fn run(
    problem: &Problem,
    threads: usize,
    path: UpdatePath,
    seed: u64,
    iters: usize,
    cyclic: bool,
) -> (SolveOutput, f64) {
    run_budget(problem, threads, path, seed, iters, cyclic, 1024)
}

/// [`run`] with an explicit buffered-update memory budget (MiB).
#[allow(clippy::too_many_arguments)]
fn run_budget(
    problem: &Problem,
    threads: usize,
    path: UpdatePath,
    seed: u64,
    iters: usize,
    cyclic: bool,
    budget_mb: usize,
) -> (SolveOutput, f64) {
    let sel: Box<dyn Select> = if cyclic {
        Box::new(Cyclic {
            next: 0,
            k: problem.n_features(),
        })
    } else {
        Box::new(RandomSubset {
            rng: Pcg64::seeded(seed),
            k: problem.n_features(),
            size: 6,
        })
    };
    let cfg = EngineConfig {
        threads,
        max_iters: iters,
        max_seconds: 60.0,
        update_path: path,
        buffer_budget_mb: budget_mb,
        ..Default::default()
    };
    let state = SharedState::new(problem.n_samples(), problem.n_features());
    let out = solve_from(problem, &state, sel, accept::all(), &cfg, EngineHooks::none());
    let drift = state.z_drift(problem);
    (out, drift)
}

const PATHS: [UpdatePath; 3] = [
    UpdatePath::Atomic,
    UpdatePath::Buffered,
    UpdatePath::ConflictFree,
];

/// At T=1 with single-coordinate (CCD) selections every discipline
/// applies the identical sequence of floating-point operations, so `w`
/// must agree *bit-exactly* — any reordering bug shows up immediately.
/// (With multi-coordinate selections the buffered path legitimately
/// re-associates same-row contributions; that case is bounded below.)
#[test]
fn single_thread_paths_bit_exact() {
    for logistic in [false, true] {
        let problem = make_problem(11, 48, 24, logistic);
        let runs: Vec<(SolveOutput, f64)> = PATHS
            .iter()
            .map(|&p| run(&problem, 1, p, 99, 400, true))
            .collect();
        for (out, drift) in &runs {
            assert!(
                *drift < 1e-9,
                "logistic={logistic}: z drifted by {drift}"
            );
            let first = out.history.records.first().unwrap().objective;
            assert!(out.objective <= first, "did not descend");
        }
        let reference = &runs[0].0;
        for (idx, (out, _)) in runs.iter().enumerate().skip(1) {
            assert_eq!(
                reference.w, out.w,
                "logistic={logistic}: path {:?} diverged bit-wise from Atomic",
                PATHS[idx]
            );
            assert_eq!(reference.objective, out.objective);
        }
    }
}

/// Under an 8-thread SHOTGUN-style run the scatter order differs between
/// disciplines, so results may differ by floating-point reassociation —
/// but the per-iteration selections and proposals are otherwise
/// identical, so the weight vectors must track each other to 1e-12.
/// The iteration count is kept modest so reassociation noise (~1e-16
/// per summand per iteration) has orders of magnitude of headroom under
/// the bound; raise iterations only with measured drift in hand.
#[test]
fn multithread_buffered_tracks_atomic() {
    let problem = make_problem(12, 64, 32, true);
    let (atomic, d_atomic) = run(&problem, 8, UpdatePath::Atomic, 5, 25, false);
    let (buffered, d_buffered) = run(&problem, 8, UpdatePath::Buffered, 5, 25, false);
    assert!(d_atomic < 1e-9, "atomic z drift {d_atomic}");
    assert!(d_buffered < 1e-9, "buffered z drift {d_buffered}");
    let max_diff = atomic
        .w
        .iter()
        .zip(&buffered.w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(
        max_diff <= 1e-12,
        "atomic vs buffered weights diverged by {max_diff}"
    );
}

/// The z_drift invariant holds for every path after a longer contended
/// run (the absolute backstop against lost or doubled updates).
#[test]
fn z_drift_invariant_all_paths() {
    let problem = make_problem(13, 80, 40, true);
    for path in [UpdatePath::Auto, UpdatePath::Atomic, UpdatePath::Buffered] {
        let (out, drift) = run(&problem, 8, path, 7, 300, false);
        assert!(
            drift < 1e-8,
            "{path:?}: z drifted by {drift} (updates {})",
            out.metrics.updates
        );
        assert!(out.objective.is_finite());
    }
}

/// The memory-budget spill path (buffered semantics without the dense
/// `n * threads` accumulators) is just another discipline: bit-exact at
/// T=1 single-coordinate selections, 1e-12 under 8-thread contention,
/// z_drift-clean, and visibly engaged via the spill_iters counter.
#[test]
fn budget_spill_matches_other_paths() {
    let problem = make_problem(14, 48, 24, true);
    // T=1, cyclic: identical FP sequence => bit-exact against atomic
    let (atomic, _) = run(&problem, 1, UpdatePath::Atomic, 3, 300, true);
    let (spill, d_spill) = run_budget(&problem, 1, UpdatePath::Buffered, 3, 300, true, 0);
    assert!(d_spill < 1e-9, "spill z drift {d_spill}");
    assert_eq!(atomic.w, spill.w, "T=1 spill diverged bit-wise from atomic");
    assert_eq!(
        spill.metrics.spill_iters, spill.metrics.iterations,
        "budget 0 must spill every iteration"
    );
    // 8 threads: reassociation-bounded agreement with the atomic path
    let (atomic, _) = run(&problem, 8, UpdatePath::Atomic, 5, 25, false);
    let (spill, d_spill) = run_budget(&problem, 8, UpdatePath::Buffered, 5, 25, false, 0);
    assert!(d_spill < 1e-9, "mt spill z drift {d_spill}");
    let max_diff = atomic
        .w
        .iter()
        .zip(&spill.w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(
        max_diff <= 1e-12,
        "atomic vs spill weights diverged by {max_diff}"
    );
    // a roomy budget keeps the dense path (no spilling)
    let (dense, _) = run_budget(&problem, 4, UpdatePath::Buffered, 5, 25, false, 1024);
    assert_eq!(dense.metrics.spill_iters, 0);
}

/// The solver config string plumbs through to the engine: a driver run
/// with solver.update_path = buffered behaves and converges like the
/// default, and an unknown name errors cleanly.
#[test]
fn driver_respects_update_path_config() {
    use gencd::config::RunConfig;
    use gencd::coordinator::driver::run_on;

    let ds = gencd::data::by_name("dorothea@0.02").unwrap();
    let mk = |path: &str| {
        let mut cfg = RunConfig::default();
        cfg.dataset.name = "dorothea@0.02".into();
        cfg.problem.lam = 1e-4;
        cfg.solver.algorithm = "shotgun".into();
        cfg.solver.threads = 4;
        cfg.solver.max_iters = 200;
        cfg.solver.max_seconds = 20.0;
        cfg.solver.update_path = path.into();
        cfg
    };
    let a = run_on(&mk("atomic"), ds.clone(), None).unwrap();
    let b = run_on(&mk("buffered"), ds.clone(), None).unwrap();
    let first = a.history.records.first().unwrap().objective;
    assert!(a.objective < first);
    assert!(b.objective < first);
    // solver.buffer_budget_mb plumbs through: budget 0 spills, converges
    let mut capped = mk("buffered");
    capped.solver.buffer_budget_mb = 0;
    let c = run_on(&capped, ds.clone(), None).unwrap();
    assert!(c.objective < first);
    assert_eq!(c.metrics.spill_iters, c.metrics.iterations);
    // conflict-free with a racy algorithm/thread combination is refused
    assert!(run_on(&mk("conflict-free"), ds.clone(), None).is_err());
    let mut single = mk("conflict-free");
    single.solver.threads = 1;
    assert!(run_on(&single, ds.clone(), None).is_ok());
    assert!(run_on(&mk("warp-drive"), ds, None).is_err());
}
