//! Differential tests for the sharded execution layer.
//!
//! * one shard reproduces the unsharded engine **bit-exactly** at T = 1
//!   (both through the builder's `shards(1)` routing and through
//!   `shard::engine::solve_sharded` directly, which exercises the
//!   reconcile-observer machinery);
//! * every `Algorithm` preset run with `shards > 1` converges to the
//!   same optimum as the unsharded solver (objective within 1e-12 on a
//!   planted squared-loss problem);
//! * the partitioner invariant (every column in exactly one shard, all
//!   strategies, including the p < shards edge case) holds through the
//!   public API;
//! * min-overlap partitioning eliminates cross-shard write conflicts on
//!   block-structured data where round-robin provokes them.

use gencd::coordinator::algorithms::{instantiate, Algorithm, Preprocessed};
use gencd::coordinator::engine::{self, EngineConfig, EngineHooks, UpdatePath};
use gencd::coordinator::problem::{Problem, SharedState};
use gencd::loss::Squared;
use gencd::shard::{partition, solve_sharded, ShardSpec, ShardStrategy, ShardedConfig};
use gencd::sparse::io::Dataset;
use gencd::sparse::{CooBuilder, CscMatrix};
use gencd::util::Pcg64;
use gencd::{Solver, SolverBuilder};

/// Random sparse design with a planted 3-coordinate signal; squared
/// loss so both solvers can reach the unique lasso optimum to machine
/// precision. Low column correlation (random signs, moderate density)
/// keeps every parallel preset stable.
fn planted_xy(seed: u64, n: usize, k: usize) -> (CscMatrix, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let mut b = CooBuilder::new(n, k);
    for j in 0..k {
        for i in 0..n {
            if rng.next_f64() < 0.25 {
                b.push(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let wstar: Vec<f64> = (0..k)
        .map(|j| if j < 3 { 1.5 } else { 0.0 })
        .collect();
    let y = x.matvec(&wstar);
    (x, y)
}

fn builder(x: &CscMatrix, y: &[f64], alg: Algorithm) -> SolverBuilder {
    Solver::builder()
        .matrix(x.clone())
        .labels(y.to_vec())
        .loss(Squared)
        .lambda(1e-2)
        .algorithm(alg)
        .seed(3)
        .max_seconds(120.0)
        .log_every(500)
}

#[test]
fn builder_shards_one_is_bit_exact() {
    // acceptance criterion: SolverBuilder::shards(1) reproduces the
    // unsharded solver bit-exactly at T = 1
    let (x, y) = planted_xy(1, 50, 20);
    for alg in [Algorithm::Ccd, Algorithm::Scd, Algorithm::Shotgun, Algorithm::Greedy] {
        let plain = builder(&x, &y, alg).max_iters(300).build().unwrap().solve();
        let sharded = builder(&x, &y, alg)
            .shards(1)
            .max_iters(300)
            .build()
            .unwrap()
            .solve();
        assert_eq!(plain.w, sharded.w, "{}: w diverged bit-wise", alg.name());
        assert_eq!(plain.objective, sharded.objective, "{}", alg.name());
    }
}

#[test]
fn shard_engine_single_shard_bit_exact_vs_engine() {
    // the stronger form: one shard driven through the full reconcile
    // observer machinery replays the raw engine bit-exactly at T = 1
    let (x, y) = planted_xy(2, 40, 16);
    let k = x.n_cols();
    let seed = 7u64;
    let iters = 500usize;
    for alg in [Algorithm::Scd, Algorithm::ThreadGreedy] {
        let mk_problem = || {
            Problem::new(
                Dataset {
                    x: x.clone(),
                    y: y.clone(),
                    name: "t".into(),
                },
                Box::new(Squared),
                1e-2,
            )
        };
        let pre = Preprocessed::for_algorithm(
            alg,
            &x,
            gencd::coloring::Strategy::Greedy,
            seed,
        );

        // raw engine, T = 1
        let inst = instantiate(alg, k, 1, 0, 0, &pre, seed).unwrap();
        let problem = mk_problem();
        let state = SharedState::new(problem.n_samples(), problem.n_features());
        let cfg = EngineConfig {
            threads: 1,
            max_iters: iters,
            max_seconds: 120.0,
            ..Default::default()
        };
        let plain = engine::solve_from(
            &problem,
            &state,
            inst.selector,
            inst.acceptor,
            &cfg,
            EngineHooks::none(),
        );

        // one-shard sharded engine: full-range zero-copy view, same
        // policy streams
        let inst = instantiate(alg, k, 1, 0, 0, &pre, seed).unwrap();
        let global = mk_problem();
        let view = global.x.col_range_view(0, k);
        let spec = ShardSpec {
            problem: Problem::new(
                Dataset {
                    x: view,
                    y: y.clone(),
                    name: String::new(),
                },
                Box::new(Squared),
                1e-2,
            ),
            cols: (0..k as u32).collect(),
            select: inst.selector,
            accept: inst.acceptor,
            update_path: UpdatePath::Auto,
            threads: 1,
        };
        let scfg = ShardedConfig {
            max_rounds: iters,
            max_seconds: 120.0,
            log_every: 100,
            ..Default::default()
        };
        let sharded = solve_sharded(&global, vec![spec], None, &scfg);

        assert_eq!(plain.w, sharded.w, "{}: w diverged bit-wise", alg.name());
        assert_eq!(plain.objective, sharded.objective, "{}", alg.name());
        assert_eq!(sharded.metrics.iterations, iters as u64);
        assert_eq!(sharded.metrics.replica_divergence, 0.0);
    }
}

#[test]
fn all_presets_sharded_converge_to_unsharded_objective() {
    // acceptance criterion: every preset solves correctly with
    // shards > 1 — run both to convergence on the planted problem and
    // compare final objectives to 1e-12
    let (x, y) = planted_xy(3, 60, 24);
    let iters = 12_000usize;
    for alg in Algorithm::ALL {
        let plain = builder(&x, &y, alg)
            .max_iters(iters)
            .build()
            .unwrap()
            .solve();
        let sharded = builder(&x, &y, alg)
            .shards(3)
            .threads(3)
            .shard_strategy(ShardStrategy::MinOverlap)
            .max_iters(iters)
            .build()
            .unwrap()
            .solve();
        assert_eq!(sharded.metrics.shards, 3, "{}", alg.name());
        let gap = (plain.objective - sharded.objective).abs();
        assert!(
            gap <= 1e-12,
            "{}: unsharded {} vs sharded {} (gap {gap:.3e})",
            alg.name(),
            plain.objective,
            sharded.objective
        );
        // the sharded result is internally consistent: reported
        // objective matches a from-scratch residual
        let p = Problem::new(
            Dataset {
                x: x.clone(),
                y: y.clone(),
                name: "check".into(),
            },
            Box::new(Squared),
            1e-2,
        );
        let z = p.x.matvec(&sharded.w);
        assert!(
            (p.objective(&sharded.w, &z) - sharded.objective).abs() < 1e-9,
            "{}: sharded z inconsistent with w",
            alg.name()
        );
    }
}

#[test]
fn partitioner_invariant_through_public_api() {
    let (x, _) = planted_xy(4, 30, 7);
    for shards in [1usize, 2, 3, 7, 12] {
        // 12 > 7 columns: the p < shards edge case
        for strategy in ShardStrategy::ALL {
            let plan = partition(&x, shards, strategy);
            plan.validate().unwrap_or_else(|e| {
                panic!("{} S={shards}: {e}", strategy.name())
            });
            let mut all: Vec<u32> = plan.permutation();
            all.sort_unstable();
            assert_eq!(all, (0..7u32).collect::<Vec<_>>());
        }
    }
}

#[test]
fn min_overlap_eliminates_conflicts_on_block_data() {
    // two feature blocks over disjoint sample halves: a min-overlap
    // partition gives conflict-free replicas (divergence == 0), while
    // round-robin forces every round's reconcile to fix real conflicts.
    // Sliding 12-row windows (stride 3) guarantee consecutive
    // same-block columns overlap, so the affinity greedy recovers the
    // blocks deterministically.
    let n_half = 30usize;
    let k_half = 10usize;
    let mut rng = Pcg64::seeded(5);
    let mut b = CooBuilder::new(2 * n_half, 2 * k_half);
    for j in 0..2 * k_half {
        let (base, jloc) = if j < k_half { (0, j) } else { (n_half, j - k_half) };
        for t in 0..12 {
            b.push(
                base + (3 * jloc + t) % n_half,
                j,
                rng.range_f64(0.2, 1.0),
            );
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let wstar: Vec<f64> = (0..2 * k_half)
        .map(|j| if j % k_half < 2 { 1.0 } else { 0.0 })
        .collect();
    let y = x.matvec(&wstar);

    let run = |strategy: ShardStrategy| {
        builder(&x, &y, Algorithm::Shotgun)
            .shards(2)
            .threads(2)
            .shard_strategy(strategy)
            .max_iters(400)
            .build()
            .unwrap()
            .solve()
    };
    let mo = run(ShardStrategy::MinOverlap);
    let rr = run(ShardStrategy::RoundRobin);
    assert_eq!(
        mo.metrics.replica_divergence, 0.0,
        "min-overlap shards must never conflict on block data"
    );
    assert!(
        rr.metrics.replica_divergence > 0.0,
        "round-robin must provoke cross-shard conflicts on block data"
    );
    assert!(mo.objective.is_finite() && rr.objective.is_finite());
}
