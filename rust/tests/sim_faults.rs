//! Acceptance tests for the fault-injection simulator (`gencd::sim`)
//! and the hardened reconcile protocol behind it:
//!
//! * the committed `scenarios/` corpus (≥ 8 files) replays green — the
//!   corpus is a regression gate, not a demo;
//! * a fault-free [`SimLink`] is transparent: its objective lands
//!   within 1e-12 of the production [`BarrierLink`] on **every**
//!   `Algorithm` preset;
//! * same seed + scenario ⇒ byte-identical event logs and bitwise
//!   identical iterates across replays (and per-preset two-run
//!   determinism, which also pins the seeded-RNG audit: no policy may
//!   depend on hash order);
//! * an injected pool kill and a virtual straggler timeout both
//!   terminate promptly with `StopReason::ShardFailed` plus a
//!   structured `SolveError` — degrade, never hang;
//! * the bounded-staleness budget forcibly reconciles a doubling
//!   adaptive cadence and counts doing so.
//!
//! [`SimLink`]: gencd::sim::SimLink
//! [`BarrierLink`]: gencd::shard::BarrierLink

use std::path::Path;
use std::time::Instant;

use gencd::coordinator::convergence::StopReason;
use gencd::sim::{run_baseline, run_corpus, run_scenario, run_scenario_logged, Scenario};

/// All eight (Select, Accept) presets, by their registry names.
const PRESETS: [&str; 8] = [
    "ccd",
    "scd",
    "shotgun",
    "thread-greedy",
    "greedy",
    "coloring",
    "topk",
    "block-shotgun",
];

/// A small fault-free scenario for `alg`, solved in well under a second
/// so the per-preset sweeps stay cheap.
fn preset_scenario(alg: &str, seed: u64) -> Scenario {
    let src = format!(
        r#"
        name = "preset-{alg}"
        seed = {seed}
        [workload]
        kind = "uniform"
        n = 60
        k = 24
        nnz = 6
        lam = 0.001
        [shards]
        count = 2
        [solve]
        algorithm = "{alg}"
        rounds = 12
        "#
    );
    Scenario::from_toml_str(&src, "preset").unwrap()
}

#[test]
fn committed_corpus_replays_green() {
    let runs = run_corpus(Path::new("scenarios"), None).expect("scenario dir must be readable");
    assert!(
        runs.len() >= 8,
        "committed corpus must hold at least 8 scenarios, found {}",
        runs.len()
    );
    for run in &runs {
        assert!(
            run.verdict.pass,
            "scenario {} failed: {}",
            run.verdict.name, run.verdict.detail
        );
    }
}

#[test]
fn fault_free_sim_matches_barrier_link_on_every_preset() {
    for alg in PRESETS {
        let sc = preset_scenario(alg, 41);
        assert!(sc.faults.is_fault_free());
        let sim = run_scenario(&sc).unwrap();
        let sim_out = sim.output.as_ref().unwrap();
        let real = run_baseline(&sc).unwrap();
        assert!(sim_out.failure.is_none(), "{alg}: {:?}", sim_out.failure);
        assert!(real.failure.is_none(), "{alg}: {:?}", real.failure);
        let (a, b) = (sim_out.objective, real.objective);
        assert!(
            (a - b).abs() <= 1e-12 * a.abs().max(1.0),
            "{alg}: simulated objective {a} vs barrier objective {b}"
        );
    }
}

#[test]
fn same_scenario_replays_byte_identical() {
    // the nastiest completing scenario: jitter + reorder + straggler on
    // the conflict workload — if anything leaks wall-clock or hash
    // order into the schedule, this is where it shows
    let src = r#"
        name = "replay-torture"
        seed = 77
        [workload]
        kind = "conflict"
        n = 90
        k = 30
        nnz = 8
        lam = 0.001
        [shards]
        count = 3
        [solve]
        rounds = 20
        [faults]
        delay_ticks_max = 9
        reorder = true
        straggler_shard = 2
        straggler_mult = 4
    "#;
    let sc = Scenario::from_toml_str(src, "x").unwrap();
    let a = run_scenario(&sc).unwrap();
    let b = run_scenario(&sc).unwrap();
    assert!(!a.event_log.is_empty());
    assert_eq!(
        a.event_log, b.event_log,
        "event logs must replay byte-identically"
    );
    let (wa, wb) = (
        &a.output.as_ref().unwrap().w,
        &b.output.as_ref().unwrap().w,
    );
    assert_eq!(wa.len(), wb.len());
    for (i, (x, y)) in wa.iter().zip(wb.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "w[{i}] differs across replays");
    }
}

#[test]
fn structured_log_replays_byte_identical_under_faults() {
    // the typed event stream inherits the replay contract: same seed +
    // scenario => the StructuredLog text lines (logical timestamps,
    // shortest-roundtrip floats) are byte-identical across runs, even
    // with jitter + reorder + straggler faults in play
    let src = r#"
        name = "logged-replay"
        seed = 19
        [workload]
        kind = "conflict"
        n = 90
        k = 30
        nnz = 8
        lam = 0.001
        [shards]
        count = 3
        [solve]
        rounds = 15
        [faults]
        delay_ticks_max = 7
        reorder = true
        straggler_shard = 1
        straggler_mult = 3
    "#;
    let sc = Scenario::from_toml_str(src, "x").unwrap();
    let (ra, la) = run_scenario_logged(&sc).unwrap();
    let (rb, lb) = run_scenario_logged(&sc).unwrap();
    assert!(ra.verdict.pass, "{}", ra.verdict.detail);
    assert!(!la.is_empty(), "structured log must capture events");
    assert_eq!(la, lb, "structured event lines must replay byte-identically");
    assert_eq!(ra.event_log, rb.event_log, "sim event logs must also match");
    // the stream covers both the iteration layer and the reconcile layer
    assert!(la.iter().any(|l| l.contains(" iteration ")), "{la:?}");
    assert!(la.iter().any(|l| l.contains(" reconcile ")), "{la:?}");
}

#[test]
fn every_preset_is_two_run_deterministic() {
    // the seeded-RNG audit's teeth: same seed, same scenario, bitwise
    // identical iterate — for every preset, so no Select/Accept policy
    // (MinOverlap partitioning included via its builder path) depends
    // on hash order or wall clock
    for alg in PRESETS {
        let sc = preset_scenario(alg, 53);
        let a = run_scenario(&sc).unwrap();
        let b = run_scenario(&sc).unwrap();
        assert_eq!(a.event_log, b.event_log, "{alg}: event logs differ");
        let (wa, wb) = (
            &a.output.as_ref().unwrap().w,
            &b.output.as_ref().unwrap().w,
        );
        for (i, (x, y)) in wa.iter().zip(wb.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{alg}: w[{i}] differs");
        }
    }
}

#[test]
fn injected_panic_terminates_structured() {
    let sc = Scenario::load(Path::new("scenarios/07-panic-mid-solve.toml")).unwrap();
    let t0 = Instant::now();
    let run = run_scenario(&sc).unwrap();
    assert!(
        t0.elapsed().as_secs() < 30,
        "killed solve must terminate promptly, took {:?}",
        t0.elapsed()
    );
    let out = run.output.as_ref().unwrap();
    assert_eq!(out.stop, StopReason::ShardFailed);
    let failure = out.failure.as_ref().expect("structured error must surface");
    assert!(
        failure.message.contains("injected fault"),
        "panic payload should surface: {failure}"
    );
    assert!(out.metrics.shard_failures >= 1);
    assert!(run.verdict.pass, "{}", run.verdict.detail);
}

#[test]
fn virtual_timeout_terminates_structured() {
    let sc = Scenario::load(Path::new("scenarios/06-straggler-timeout.toml")).unwrap();
    let t0 = Instant::now();
    let run = run_scenario(&sc).unwrap();
    assert!(
        t0.elapsed().as_secs() < 30,
        "timed-out solve must terminate promptly, took {:?}",
        t0.elapsed()
    );
    let out = run.output.as_ref().unwrap();
    assert_eq!(out.stop, StopReason::ShardFailed);
    let failure = out.failure.as_ref().expect("structured error must surface");
    assert!(
        failure.message.contains("timed out"),
        "timeout cause should surface: {failure}"
    );
    assert!(run.verdict.pass, "{}", run.verdict.detail);
}

#[test]
fn staleness_budget_forces_reconciles() {
    let sc = Scenario::load(Path::new("scenarios/08-staleness-clamp.toml")).unwrap();
    let run = run_scenario(&sc).unwrap();
    let out = run.output.as_ref().unwrap();
    assert_eq!(out.stop, StopReason::MaxIters);
    assert!(
        out.metrics.staleness_forced_reconciles >= 1,
        "doubling cadence must hit the staleness clamp, metrics: {}",
        out.metrics.staleness_forced_reconciles
    );
    assert!(run.verdict.pass, "{}", run.verdict.detail);
}
