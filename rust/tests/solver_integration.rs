//! Cross-module integration: the full solver against known-answer
//! problems, algorithm agreement, coloring safety under the real
//! engine, and serialization round-trips through the driver.

use gencd::config::RunConfig;
use gencd::coordinator::driver::{run, run_on};
use gencd::coordinator::problem::Problem;
use gencd::coordinator::Algorithm;
use gencd::data::{self, GenOptions};
use gencd::loss::{self, Squared};
use gencd::sparse::io::Dataset;
use gencd::sparse::CooBuilder;
use gencd::util::prop;
use gencd::util::Pcg64;

/// With X = I (orthonormal design) and squared loss, the lasso solution
/// is the soft threshold: F = (1/n) sum 0.5 (y_i - w_i)^2 has
/// d/dw_j = (w_j - y_j)/n and curvature 1/n, so the minimizer of
/// F + lam |w|_1 is w_j = soft_threshold(y_j, n * lam).
#[test]
fn lasso_identity_design_closed_form() {
    let n = 16;
    let mut b = CooBuilder::new(n, n);
    for i in 0..n {
        b.push(i, i, 1.0);
    }
    let x = b.build();
    let mut rng = Pcg64::seeded(5);
    let y: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let lam = 0.02;
    let ds = Dataset {
        x,
        y: y.clone(),
        name: "identity".into(),
    };
    let problem = Problem::new(ds.clone(), Box::new(Squared), lam);

    let mut cfg = RunConfig::default();
    cfg.problem.loss = "squared".into();
    cfg.problem.lam = lam;
    cfg.dataset.normalize = false; // already unit columns
    cfg.solver.algorithm = "ccd".into();
    cfg.solver.threads = 1;
    cfg.solver.max_iters = 2000;
    cfg.solver.max_seconds = 30.0;
    let res = run_on(&cfg, ds, None).unwrap();

    let tau = n as f64 * lam;
    for (j, &wj) in res.w.iter().enumerate() {
        let want = gencd::util::soft_threshold(y[j], tau);
        assert!(
            (wj - want).abs() < 1e-8,
            "w[{j}] = {wj}, closed form {want}"
        );
    }
    let w_star: Vec<f64> = y
        .iter()
        .map(|&v| gencd::util::soft_threshold(v, tau))
        .collect();
    let z_star = problem.x.matvec(&w_star);
    assert!((res.objective - problem.objective(&w_star, &z_star)).abs() < 1e-10);
}

/// All algorithms must approach the same optimum on a well-conditioned
/// problem (global convergence of CD for separable l1 objectives).
#[test]
fn algorithms_agree_on_optimum() {
    let ds = data::by_name("reuters@0.02").unwrap();
    let lam = 1e-4;
    let mut objectives = Vec::new();
    for alg in [
        Algorithm::Ccd,
        Algorithm::Scd,
        Algorithm::Shotgun,
        Algorithm::ThreadGreedy,
        Algorithm::Greedy,
        Algorithm::Coloring,
        Algorithm::TopK,
        Algorithm::BlockShotgun,
    ] {
        let mut cfg = RunConfig::default();
        cfg.dataset.name = "reuters@0.02".into();
        cfg.problem.lam = lam;
        cfg.solver.algorithm = alg.name().into();
        cfg.solver.threads = 2;
        cfg.solver.max_seconds = 6.0;
        cfg.solver.line_search_steps = 5;
        let res = run_on(&cfg, ds.clone(), None).unwrap();
        objectives.push((alg.name(), res.objective));
    }
    let best = objectives
        .iter()
        .map(|(_, o)| *o)
        .fold(f64::INFINITY, f64::min);
    for (name, obj) in &objectives {
        assert!(
            (obj - best) / best < 0.25,
            "{name} landed at {obj}, best {best} (all: {objectives:?})"
        );
    }
}

/// COLORING with many threads must leave z consistent with w (its color
/// classes are conflict-free, so no update may be lost or doubled).
#[test]
fn coloring_concurrent_updates_consistent() {
    let ds = data::by_name("dorothea@0.05").unwrap();
    let mut cfg = RunConfig::default();
    cfg.dataset.name = "dorothea@0.05".into();
    cfg.problem.lam = 1e-4;
    cfg.solver.algorithm = "coloring".into();
    cfg.solver.threads = 8;
    cfg.solver.max_iters = 400;
    cfg.solver.max_seconds = 20.0;
    let res = run_on(&cfg, ds, None).unwrap();
    let ds2 = {
        let mut d = data::by_name("dorothea@0.05").unwrap();
        d.x.normalize_columns();
        d
    };
    let problem = Problem::new(ds2, loss::by_name("logistic").unwrap(), 1e-4);
    let z = problem.x.matvec(&res.w);
    let obj = problem.objective(&res.w, &z);
    assert!(
        (obj - res.objective).abs() < 1e-9,
        "reported {} vs recomputed {obj}",
        res.objective
    );
}

/// Shotgun past the P* bound on a pathological (perfectly correlated)
/// design diverges or stalls — the behaviour the Accept step exists to
/// prevent (Sec. 2.3) — while P*-sized selection stays stable.
#[test]
fn shotgun_divergence_cliff_on_correlated_design() {
    // 64 identical columns: rho = 64 after normalization, P* -> 1
    let n = 32;
    let k = 64;
    let mut b = CooBuilder::new(n, k);
    let mut rng = Pcg64::seeded(9);
    let col: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 1.0)).collect();
    for j in 0..k {
        for (i, &v) in col.iter().enumerate() {
            b.push(i, j, v);
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let y: Vec<f64> = (0..n)
        .map(|i| if i % 2 == 0 { 2.0 } else { -2.0 })
        .collect();
    let ds = Dataset {
        x,
        y,
        name: "correlated".into(),
    };

    let run_size = |size: usize| {
        let mut cfg = RunConfig::default();
        cfg.problem.loss = "squared".into();
        cfg.problem.lam = 1e-6;
        cfg.dataset.normalize = false;
        cfg.solver.algorithm = "shotgun".into();
        cfg.solver.select_size = size;
        cfg.solver.threads = 2;
        cfg.solver.max_iters = 3000;
        cfg.solver.max_seconds = 10.0;
        cfg.solver.log_every = 25;
        run_on(&cfg, ds.clone(), None).unwrap()
    };
    let safe = run_size(1); // P* = 1
    assert!(
        safe.objective.is_finite()
            && safe.stop != gencd::coordinator::convergence::StopReason::Diverged,
        "safe run should converge, got {} ({:?})",
        safe.objective,
        safe.stop
    );
    let wild = run_size(64); // way past P*
    assert!(
        wild.stop == gencd::coordinator::convergence::StopReason::Diverged
            || wild.objective > safe.objective * 2.0,
        "expected divergence or stall past P*: safe {} wild {} ({:?})",
        safe.objective,
        wild.objective,
        wild.stop
    );
}

/// Dataset IO round-trip through the driver (path-based loading).
#[test]
fn driver_loads_from_files() {
    let dir = std::env::temp_dir().join("gencd_solver_int");
    std::fs::create_dir_all(&dir).unwrap();
    let ds = data::dorothea_like(&GenOptions {
        scale: 0.02,
        ..Default::default()
    });
    let bin = dir.join("d.bin");
    gencd::sparse::io::write_binary(&ds, &bin).unwrap();
    let svm = dir.join("d.libsvm");
    gencd::sparse::io::write_libsvm(&ds, std::fs::File::create(&svm).unwrap()).unwrap();

    for path in [bin.to_str().unwrap(), svm.to_str().unwrap()] {
        let mut cfg = RunConfig::default();
        cfg.dataset.path = Some(path.to_string());
        cfg.problem.lam = 1e-3;
        cfg.solver.algorithm = "scd".into();
        cfg.solver.threads = 1;
        cfg.solver.max_iters = 50;
        let res = run(&cfg).unwrap();
        assert!(res.objective.is_finite());
        assert_eq!(res.w.len(), ds.n_features());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Property: random small problems, random algorithms/threads — final
/// objective never worse than initial; reported nnz consistent.
#[test]
fn prop_all_algorithms_sane_on_random_problems() {
    prop::check("algorithms sane", 8, |rng, _| {
        let algs = ["scd", "shotgun", "thread-greedy", "coloring"];
        let alg = algs[rng.below(algs.len())];
        let scale = 0.01 + rng.next_f64() * 0.02;
        let mut cfg = RunConfig::default();
        cfg.dataset.name = format!("reuters@{scale:.3}");
        cfg.problem.lam = 10f64.powf(rng.range_f64(-5.0, -3.0));
        cfg.solver.algorithm = alg.into();
        cfg.solver.threads = 1 + rng.below(4);
        cfg.solver.max_iters = 150;
        cfg.solver.max_seconds = 10.0;
        cfg.solver.seed = rng.next_u64();
        let res = run(&cfg).map_err(|e| e.to_string())?;
        let first = res.history.records.first().unwrap().objective;
        prop::ensure(
            res.objective <= first + 1e-9,
            format!("{alg}: {first} -> {}", res.objective),
        )?;
        let nnz = res.w.iter().filter(|w| **w != 0.0).count();
        prop::ensure(nnz == res.nnz, format!("{alg}: nnz mismatch"))
    });
}
