//! Differential tests for the runtime-dispatched SIMD kernel tiers
//! (`gencd::kernel`): every dispatched arm against the plain scalar
//! reference at the kernel level (100 seeded ragged column shapes), and
//! the tiers against the reference engine across all eight presets at
//! T = 1 and T = 4 — plus the screened, sharded and forced-scalar
//! surfaces.
//!
//! The agreement bars mirror the module's bit-exactness discipline:
//! **axpy** arms must match the scalar scatter *bit for bit* (each
//! element touched once, multiply-then-add, no FMA contraction), while
//! **dot**/reduction arms re-associate the sum (lanes, split
//! accumulators), so they get 1e-12 at the kernel level and the
//! established solve-level bounds (1e-9 objective / 1e-7 weights) at
//! the engine level.
//!
//! One test mutates `GENCD_FORCE_SCALAR`; process environment is shared
//! across the binary's test threads, so every test here serializes on a
//! file-local lock instead of racing the dispatcher.

use std::sync::{Mutex, MutexGuard, OnceLock};

use gencd::coordinator::algorithms::Algorithm;
use gencd::kernel::{
    self, axpy_scatter_ptr, dot_dense, dot_gather, sum_abs, KernelChoice, KernelTier,
    FORCE_SCALAR_ENV,
};
use gencd::loss::Squared;
use gencd::sparse::{CooBuilder, CscMatrix};
use gencd::util::Pcg64;
use gencd::{Solver, SolverBuilder};

/// Serializes every test in this binary: `force_scalar_env_pins_dispatch`
/// flips `GENCD_FORCE_SCALAR`, which [`kernel::dispatch`] re-reads on
/// every call, and the engine-level tests assert on the dispatched tier.
fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

const TIERS: [KernelTier; 3] = [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512];

/// One seeded ragged column: strictly increasing rows over `0..n`
/// (the CSC invariant the AVX-512 scatter relies on), values and a
/// dense operand drawn from the same stream.
struct Shape {
    rows: Vec<u32>,
    vals: Vec<f64>,
    d: Vec<f64>,
    alpha: f64,
}

/// 100 shapes: every lane/unroll boundary (empty, sub-lane, 4/8/16 ±1,
/// 64 ±1) over a few dense lengths, topped up with random ragged
/// columns — the gather/scatter remainder loops see every phase.
fn shapes() -> Vec<Shape> {
    let mut rng = Pcg64::seeded(0xC0DE);
    let mut out = Vec::new();
    let boundary_lens = [
        0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65,
    ];
    for &n in &[70usize, 128, 300] {
        for &len in &boundary_lens {
            out.push(make_shape(&mut rng, n, len.min(n)));
        }
    }
    while out.len() < 100 {
        let n = 1 + (rng.next_f64() * 600.0) as usize;
        let len = (rng.next_f64() * n as f64) as usize;
        out.push(make_shape(&mut rng, n, len));
    }
    out
}

fn make_shape(rng: &mut Pcg64, n: usize, len: usize) -> Shape {
    let mut rows: Vec<u32> = rng
        .sample_distinct(n, len)
        .into_iter()
        .map(|i| i as u32)
        .collect();
    rows.sort_unstable();
    let vals: Vec<f64> = rows.iter().map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let d: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
    let alpha = rng.range_f64(-1.5, 1.5);
    Shape { rows, vals, d, alpha }
}

/// Every dispatched gather-dot and dense-reduction arm agrees with a
/// plain scalar loop to 1e-12 relative on 100 ragged shapes. (The arms
/// re-associate, so bitwise equality is *not* the contract here.)
#[test]
fn dispatched_dots_match_scalar_reference_on_ragged_shapes() {
    let _g = env_lock();
    for (si, s) in shapes().iter().enumerate() {
        let reference: f64 = s
            .rows
            .iter()
            .zip(&s.vals)
            .map(|(&i, &v)| v * s.d[i as usize])
            .sum();
        let dense_ref: f64 = s.d.iter().map(|&x| x * x).sum();
        let abs_ref: f64 = s.d.iter().map(|x| x.abs()).sum();
        for tier in TIERS {
            // SAFETY: rows index into d (sample_distinct draws from
            // 0..d.len()) and rows/vals are the same length
            let got = unsafe { dot_gather(tier, &s.rows, &s.vals, &s.d) };
            let tol = 1e-12 * reference.abs().max(1.0);
            assert!(
                (got - reference).abs() <= tol,
                "shape {si} ({} nnz) {tier:?}: dot {got} vs scalar {reference}",
                s.rows.len()
            );
            let got = dot_dense(tier, &s.d, &s.d);
            assert!(
                (got - dense_ref).abs() <= 1e-12 * dense_ref.max(1.0),
                "shape {si} {tier:?}: dot_dense {got} vs {dense_ref}"
            );
            let got = sum_abs(tier, &s.d);
            assert!(
                (got - abs_ref).abs() <= 1e-12 * abs_ref.max(1.0),
                "shape {si} {tier:?}: sum_abs {got} vs {abs_ref}"
            );
        }
    }
}

/// Every dispatched axpy-scatter arm is **bit-identical** to the plain
/// scalar scatter on the same 100 shapes — the invariant that lets the
/// engine swap tiers mid-catalogue without moving the Update math.
#[test]
fn dispatched_axpy_is_bit_identical_on_ragged_shapes() {
    let _g = env_lock();
    for (si, s) in shapes().iter().enumerate() {
        let mut reference = s.d.clone();
        for (&i, &v) in s.rows.iter().zip(&s.vals) {
            reference[i as usize] += s.alpha * v;
        }
        for tier in TIERS {
            let mut y = s.d.clone();
            // SAFETY: y outlives the call, rows index into it and are
            // strictly increasing (sorted distinct samples), and no
            // other thread touches it
            unsafe { axpy_scatter_ptr(tier, &s.rows, &s.vals, s.alpha, y.as_mut_ptr()) };
            for (j, (a, b)) in reference.iter().zip(&y).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "shape {si} ({} nnz) {tier:?}: axpy differs at {j}: {a} vs {b}",
                    s.rows.len()
                );
            }
        }
    }
}

/// Random sparse design with a planted 3-coordinate signal (the
/// construction shared with `rust/tests/sharding.rs`).
fn planted_xy(seed: u64, n: usize, k: usize) -> (CscMatrix, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let mut b = CooBuilder::new(n, k);
    for j in 0..k {
        for i in 0..n {
            if rng.next_f64() < 0.25 {
                b.push(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let wstar: Vec<f64> = (0..k).map(|j| if j < 3 { 1.5 } else { 0.0 }).collect();
    let y = x.matvec(&wstar);
    (x, y)
}

fn builder(x: &CscMatrix, y: &[f64], alg: Algorithm) -> SolverBuilder {
    Solver::builder()
        .matrix(x.clone())
        .labels(y.to_vec())
        .loss(Squared)
        .lambda(1e-2)
        .algorithm(alg)
        .seed(17)
        .max_seconds(120.0)
        .log_every(200)
}

const CHOICES: [KernelChoice; 3] =
    [KernelChoice::Scalar, KernelChoice::Avx2, KernelChoice::Avx512];

/// Engine-level differential across the whole catalogue: every preset,
/// at T = 1 and T = 4, solved with each requested tier, agrees with the
/// plain-scalar reference engine to the solve-level bounds — and the
/// metrics report the tier that actually ran (the requested one clamped
/// to this host), never the requested name.
#[test]
fn all_presets_agree_across_kernel_tiers() {
    let _g = env_lock();
    let (x, y) = planted_xy(21, 50, 20);
    for alg in Algorithm::ALL {
        for threads in [1usize, 4] {
            let reference = builder(&x, &y, alg)
                .threads(threads)
                .fast_kernels(false)
                .max_iters(300)
                .build()
                .unwrap()
                .solve();
            assert_eq!(reference.metrics.kernel_tier, "reference", "{}", alg.name());
            for choice in CHOICES {
                let fast = builder(&x, &y, alg)
                    .threads(threads)
                    .fast_kernels(true)
                    .kernel(choice)
                    .max_iters(300)
                    .build()
                    .unwrap()
                    .solve();
                // the requested tier is a ceiling, never a floor
                let ran = kernel::dispatch(choice);
                let ceiling = match choice {
                    KernelChoice::Scalar => KernelTier::Scalar,
                    KernelChoice::Avx2 => KernelTier::Avx2,
                    KernelChoice::Auto | KernelChoice::Avx512 => KernelTier::Avx512,
                };
                assert!(ran <= ceiling, "{choice:?} dispatched above its ceiling");
                assert_eq!(
                    fast.metrics.kernel_tier,
                    ran.name(),
                    "{} T={threads} {choice:?}",
                    alg.name()
                );
                let gap = (reference.objective - fast.objective).abs();
                assert!(
                    gap <= 1e-9,
                    "{} T={threads} {choice:?}: objective {} vs {} (gap {gap:.3e})",
                    alg.name(),
                    reference.objective,
                    fast.objective
                );
                for (j, (a, b)) in reference.w.iter().zip(&fast.w).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-7,
                        "{} T={threads} {choice:?}: w[{j}] {a} vs {b}",
                        alg.name()
                    );
                }
            }
        }
    }
}

/// The fast tiers compose with the other execution modes: a screened
/// solve and a 2-shard solve both track their scalar-reference twins,
/// and the dispatched tier surfaces through the aggregated sharded
/// metrics (first non-empty pool snapshot wins — all pools share one
/// config).
#[test]
fn screened_and_sharded_solves_agree_and_report_tier() {
    let _g = env_lock();
    let (x, y) = planted_xy(22, 60, 24);
    let auto_tier = kernel::dispatch(KernelChoice::Auto).name();

    let run_screened = |fast: bool| {
        builder(&x, &y, Algorithm::Scd)
            .screening(true)
            .fast_kernels(fast)
            .max_iters(2_000)
            .build()
            .unwrap()
            .solve()
    };
    let reference = run_screened(false);
    let fast = run_screened(true);
    assert_eq!(fast.metrics.kernel_tier, auto_tier);
    let gap = (reference.objective - fast.objective).abs();
    assert!(gap <= 1e-9, "screened: gap {gap:.3e}");

    let run_sharded = |fast: bool| {
        builder(&x, &y, Algorithm::Shotgun)
            .shards(2)
            .threads(2)
            .fast_kernels(fast)
            .max_iters(2_000)
            .build()
            .unwrap()
            .solve()
    };
    let reference = run_sharded(false);
    let fast = run_sharded(true);
    assert_eq!(reference.metrics.shards, 2);
    assert_eq!(reference.metrics.kernel_tier, "reference");
    assert_eq!(fast.metrics.kernel_tier, auto_tier);
    let gap = (reference.objective - fast.objective).abs();
    assert!(gap <= 1e-9, "sharded: gap {gap:.3e}");
}

/// `GENCD_FORCE_SCALAR` pins [`kernel::dispatch`] to the scalar tier
/// for every request (the CI kernel-matrix lever), is re-read per call
/// (unset restores hardware dispatch within one process), and `0` means
/// off. The only test in the suite that mutates the environment — it
/// holds the same lock as every other test here.
#[test]
fn force_scalar_env_pins_dispatch() {
    let _g = env_lock();
    // the CI scalar lane exports the hatch for the whole process; put
    // whatever was there back when done
    let prior = std::env::var(FORCE_SCALAR_ENV).ok();
    std::env::set_var(FORCE_SCALAR_ENV, "1");
    for choice in [
        KernelChoice::Auto,
        KernelChoice::Scalar,
        KernelChoice::Avx2,
        KernelChoice::Avx512,
    ] {
        assert_eq!(
            kernel::dispatch(choice),
            KernelTier::Scalar,
            "{choice:?} must pin to scalar under {FORCE_SCALAR_ENV}"
        );
    }

    // a whole solve under the hatch reports the pinned tier
    let (x, y) = planted_xy(23, 40, 16);
    let out = builder(&x, &y, Algorithm::Shotgun)
        .threads(2)
        .fast_kernels(true)
        .kernel(KernelChoice::Avx512)
        .max_iters(200)
        .build()
        .unwrap()
        .solve();
    assert_eq!(out.metrics.kernel_tier, "scalar");
    assert!(out.objective.is_finite());

    // "0" disarms the hatch; unsetting restores hardware dispatch
    std::env::set_var(FORCE_SCALAR_ENV, "0");
    assert_eq!(kernel::dispatch(KernelChoice::Scalar), KernelTier::Scalar);
    assert!(kernel::dispatch(KernelChoice::Avx512) >= kernel::dispatch(KernelChoice::Avx2));
    std::env::remove_var(FORCE_SCALAR_ENV);
    assert!(kernel::dispatch(KernelChoice::Auto) >= KernelTier::Scalar);

    if let Some(v) = prior {
        std::env::set_var(FORCE_SCALAR_ENV, v);
    }
}
