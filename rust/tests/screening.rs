//! Differential tests for the active-set screening layer
//! (`gencd::screen`).
//!
//! * screening **off** (the default) reproduces the raw engine
//!   bit-exactly at T = 1 — none of the screening machinery may touch
//!   the unscreened path;
//! * every `Algorithm` preset run with screening **on** converges to
//!   the same optimum as the unscreened solver (objective within 1e-12
//!   on a planted squared-loss problem) — the convergence-safety
//!   acceptance criterion;
//! * a tolerance stop under screening is upgraded to
//!   `StopReason::Converged` only through the gating full-set KKT
//!   sweep, and the final iterate certifies;
//! * `MetricsSnapshot::active_cols` shrinks below the feature count on
//!   the planted l1 problem while never dropping below the support;
//! * screening composes with the sharded execution layer (one active
//!   set per shard pool).

use gencd::coordinator::algorithms::{instantiate, Algorithm, Preprocessed};
use gencd::coordinator::convergence::StopReason;
use gencd::coordinator::engine::{self, EngineConfig, EngineHooks};
use gencd::coordinator::kkt;
use gencd::coordinator::problem::{Problem, SharedState};
use gencd::loss::Squared;
use gencd::shard::ShardStrategy;
use gencd::sparse::io::Dataset;
use gencd::sparse::{CooBuilder, CscMatrix};
use gencd::util::Pcg64;
use gencd::{Solver, SolverBuilder};

/// Random sparse design with a planted 3-coordinate signal; squared
/// loss so both solvers can reach the unique lasso optimum to machine
/// precision (the same construction as `rust/tests/sharding.rs`).
fn planted_xy(seed: u64, n: usize, k: usize) -> (CscMatrix, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let mut b = CooBuilder::new(n, k);
    for j in 0..k {
        for i in 0..n {
            if rng.next_f64() < 0.25 {
                b.push(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let wstar: Vec<f64> = (0..k)
        .map(|j| if j < 3 { 1.5 } else { 0.0 })
        .collect();
    let y = x.matvec(&wstar);
    (x, y)
}

fn problem(x: &CscMatrix, y: &[f64], lam: f64) -> Problem {
    Problem::new(
        Dataset {
            x: x.clone(),
            y: y.to_vec(),
            name: "screen-t".into(),
        },
        Box::new(Squared),
        lam,
    )
}

fn builder(x: &CscMatrix, y: &[f64], alg: Algorithm) -> SolverBuilder {
    Solver::builder()
        .matrix(x.clone())
        .labels(y.to_vec())
        .loss(Squared)
        .lambda(1e-2)
        .algorithm(alg)
        .seed(3)
        .max_seconds(120.0)
        .log_every(500)
}

#[test]
fn screening_off_is_bit_exact_vs_raw_engine() {
    // acceptance criterion: with screening off (the default) the
    // builder path replays the raw engine bit-for-bit at T = 1 — the
    // screening machinery must not exist on that path
    let (x, y) = planted_xy(1, 40, 16);
    let k = x.n_cols();
    for alg in [Algorithm::Ccd, Algorithm::Scd, Algorithm::Shotgun] {
        let built = builder(&x, &y, alg).max_iters(400).build().unwrap().solve();

        let pre = Preprocessed::for_algorithm(alg, &x, gencd::coloring::Strategy::Greedy, 3);
        let inst = instantiate(alg, k, 1, 0, 0, &pre, 3).unwrap();
        let p = problem(&x, &y, 1e-2);
        let state = SharedState::new(p.n_samples(), p.n_features());
        let cfg = EngineConfig {
            threads: 1,
            max_iters: 400,
            max_seconds: 120.0,
            log_every: 500,
            ..Default::default()
        };
        let raw = engine::solve_from(
            &p,
            &state,
            inst.selector,
            inst.acceptor,
            &cfg,
            EngineHooks::none(),
        );
        assert_eq!(built.w, raw.w, "{}: w diverged bit-wise", alg.name());
        assert_eq!(built.objective, raw.objective, "{}", alg.name());
        assert_eq!(built.metrics.active_cols, 0);
        assert_eq!(built.metrics.kkt_passes, 0);
    }
}

#[test]
fn all_presets_screened_match_unscreened_objective() {
    // acceptance criterion: screening is convergence-safe for every
    // preset — run both to convergence on the planted problem and
    // compare final objectives to 1e-12
    let (x, y) = planted_xy(3, 60, 24);
    let iters = 12_000usize;
    for alg in Algorithm::ALL {
        let plain = builder(&x, &y, alg)
            .max_iters(iters)
            .build()
            .unwrap()
            .solve();
        let screened = builder(&x, &y, alg)
            .screening(true)
            .kkt_every(16)
            .max_iters(iters)
            .build()
            .unwrap()
            .solve();
        let gap = (plain.objective - screened.objective).abs();
        assert!(
            gap <= 1e-12,
            "{}: unscreened {} vs screened {} (gap {gap:.3e})",
            alg.name(),
            plain.objective,
            screened.objective
        );
        assert!(
            screened.metrics.kkt_passes >= 1,
            "{}: the safety sweep must have run",
            alg.name()
        );
        // the screened result is internally consistent: reported
        // objective matches a from-scratch residual
        let p = problem(&x, &y, 1e-2);
        let z = p.x.matvec(&screened.w);
        assert!(
            (p.objective(&screened.w, &z) - screened.objective).abs() < 1e-9,
            "{}: screened z inconsistent with w",
            alg.name()
        );
    }
}

#[test]
fn converged_is_gated_by_a_clean_sweep() {
    let (x, y) = planted_xy(5, 40, 16);
    let screened = builder(&x, &y, Algorithm::Ccd)
        .screening(true)
        .kkt_every(8)
        .tol(1e-10)
        .log_every(10)
        .build()
        .unwrap()
        .solve();
    assert_eq!(
        screened.stop,
        StopReason::Converged,
        "a screened tolerance stop must arrive as Converged"
    );
    assert!(screened.metrics.kkt_passes >= 1, "the gate sweep must run");
    // the certificate: every frozen coordinate satisfies KKT exactly,
    // so the full violation is only the tol-level slop of the active
    // coordinates
    let p = problem(&x, &y, 1e-2);
    let report = kkt::check(&p, &screened.w, 1e-8);
    assert!(
        report.max_violation < 1e-5,
        "converged iterate far from stationary: {report:?}"
    );
    // the unscreened solver under the same tol agrees on the optimum
    // (and keeps reporting Tolerance)
    let plain = builder(&x, &y, Algorithm::Ccd)
        .tol(1e-10)
        .log_every(10)
        .build()
        .unwrap()
        .solve();
    assert_eq!(plain.stop, StopReason::Tolerance);
    assert!(
        (plain.objective - screened.objective).abs() < 1e-9,
        "{} vs {}",
        plain.objective,
        screened.objective
    );
}

#[test]
fn active_cols_shrink_below_p_and_cover_the_support() {
    let (x, y) = planted_xy(7, 80, 40);
    let k = x.n_cols();
    let out = builder(&x, &y, Algorithm::Shotgun)
        .screening(true)
        .max_iters(6_000)
        .build()
        .unwrap()
        .solve();
    assert!(
        out.metrics.active_cols > 0 && (out.metrics.active_cols as usize) < k,
        "active set must shrink below p: {} of {k}",
        out.metrics.active_cols
    );
    assert!(
        out.metrics.active_cols >= out.nnz as u64,
        "the support (nnz = {}) can never be deactivated, active = {}",
        out.nnz,
        out.metrics.active_cols
    );
    assert!(out.metrics.kkt_passes >= 1);
}

#[test]
fn sharded_screened_solve_matches_unscreened_unsharded() {
    // screening composes with the sharded layer: one active set per
    // shard pool, reactivation sweeps at round boundaries
    let (x, y) = planted_xy(9, 60, 24);
    let iters = 12_000usize;
    let plain = builder(&x, &y, Algorithm::Shotgun)
        .max_iters(iters)
        .build()
        .unwrap()
        .solve();
    let sharded = builder(&x, &y, Algorithm::Shotgun)
        .screening(true)
        .shards(3)
        .threads(3)
        .shard_strategy(ShardStrategy::MinOverlap)
        .max_iters(iters)
        .build()
        .unwrap()
        .solve();
    assert_eq!(sharded.metrics.shards, 3);
    let gap = (plain.objective - sharded.objective).abs();
    assert!(
        gap <= 1e-12,
        "unscreened-unsharded {} vs screened-sharded {} (gap {gap:.3e})",
        plain.objective,
        sharded.objective
    );
    // per-shard active sets sum below the column count and cover the
    // support; sweeps ran in every pool
    assert!(
        sharded.metrics.active_cols > 0
            && (sharded.metrics.active_cols as usize) < x.n_cols(),
        "summed active sets must shrink: {} of {}",
        sharded.metrics.active_cols,
        x.n_cols()
    );
    assert!(sharded.metrics.active_cols >= sharded.nnz as u64);
    assert!(sharded.metrics.kkt_passes >= 3, "every pool sweeps");
}

#[test]
fn sharded_screened_tolerance_stop_is_gated() {
    // the cross-shard gate: the coordinator refuses a tolerance stop
    // while any zero-weight coordinate of the global iterate violates
    // KKT, and a clean pass arrives as Converged (never Tolerance)
    let (x, y) = planted_xy(13, 40, 16);
    let out = builder(&x, &y, Algorithm::Shotgun)
        .screening(true)
        .shards(2)
        .threads(2)
        .tol(1e-10)
        .log_every(10)
        .build()
        .unwrap()
        .solve();
    assert_eq!(out.stop, StopReason::Converged);
    let p = problem(&x, &y, 1e-2);
    let report = kkt::check(&p, &out.w, 1e-8);
    assert!(
        report.max_violation < 1e-5,
        "gated sharded iterate far from stationary: {report:?}"
    );
}

#[test]
fn screened_fast_kernels_still_safe() {
    // the fused sweep through the unrolled gather and the scalar sweep
    // land on the same optimum
    let (x, y) = planted_xy(11, 50, 20);
    let run = |fast: bool| {
        builder(&x, &y, Algorithm::Ccd)
            .screening(true)
            .fast_kernels(fast)
            .max_iters(8_000)
            .build()
            .unwrap()
            .solve()
    };
    let scalar = run(false);
    let fast = run(true);
    assert!(
        (scalar.objective - fast.objective).abs() < 1e-10,
        "{} vs {}",
        scalar.objective,
        fast.objective
    );
}
