//! Acceptance tests for crash recovery (`gencd::recover`):
//!
//! * checkpoint codec robustness — 100 seeded checkpoints round-trip
//!   bitwise; every truncation prefix and every seeded byte corruption
//!   of a valid file decodes to a typed `CheckpointError`, never a
//!   panic;
//! * bit-exact resume — on **every** `Algorithm` preset, a solve cut at
//!   round 5 and resumed from its checkpoint reproduces the
//!   uninterrupted solve's final iterate bit-for-bit (exact wire
//!   precision, fixed cadence, one worker per pool);
//! * builder validation — a checkpoint offered to the wrong solve
//!   (seed, λ, shard count, shapes) is refused with a typed error;
//! * reconnect backoff — the schedule is bounded and its worst case
//!   sits far inside the 30 s degrade ceiling;
//! * the recovery corpus (`scenarios/net/03..05`) terminates promptly
//!   with the expected verdicts: transient drops heal transparently,
//!   exhausted retries degrade to a link-kind `ShardFailed`, and the
//!   checkpoint/resume drill lands within 1e-12 of its reference.

use std::path::{Path, PathBuf};
use std::time::Instant;

use gencd::coordinator::convergence::{SolveErrorKind, StopReason};
use gencd::coordinator::engine::SolveOutput;
use gencd::event::MetricsAggregator;
use gencd::net::{Transport, WirePrecision};
use gencd::recover::harness::{DrillMode, DrillSpec};
use gencd::recover::{Checkpoint, CheckpointError, ReconnectPolicy};
use gencd::sim::{run_scenario_loopback, Scenario};
use gencd::sparse::CscMatrix;
use gencd::util::Pcg64;
use gencd::Solver;

/// All eight (Select, Accept) presets, by their registry names.
const PRESETS: [&str; 8] = [
    "ccd",
    "scd",
    "shotgun",
    "thread-greedy",
    "greedy",
    "coloring",
    "topk",
    "block-shotgun",
];

const BASE: &str = r#"
    name = "recover-unit-base"
    seed = 9
    [workload]
    kind = "uniform"
    n = 60
    k = 24
    nnz = 6
    lam = 0.001
    [shards]
    count = 2
    [solve]
    rounds = 12
"#;

fn workload() -> (CscMatrix, Vec<f64>) {
    Scenario::from_toml_str(BASE, "x").unwrap().workload()
}

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gencd-recover-test-{}-{tag}.ckpt", std::process::id()))
}

/// One 2-shard loopback solve of the shared workload under the
/// bit-parity scope: exact precision, tol 0, one worker per pool.
fn solve_with(
    alg: &str,
    iters: usize,
    checkpoint: Option<&Path>,
    resume: Option<&Path>,
) -> SolveOutput {
    let (x, y) = workload();
    let mut b = Solver::builder()
        .matrix(x)
        .labels(y)
        .lambda(1e-3)
        .algorithm(alg.parse().unwrap())
        .threads(2)
        .shards(2)
        .max_iters(iters)
        .tol(0.0)
        .seed(7)
        .transport(Transport::Loopback { precision: WirePrecision::Exact });
    if let Some(path) = checkpoint {
        b = b
            .checkpoint_path(path.to_path_buf())
            .checkpoint_every_rounds(1);
    }
    if let Some(path) = resume {
        b = b.resume_from(path.to_path_buf());
    }
    b.build().unwrap().solve()
}

/// A structurally valid checkpoint with seeded contents.
fn seeded_checkpoint(rng: &mut Pcg64) -> Checkpoint {
    let n_w = 1 + (rng.next_u64() % 40) as usize;
    let n_z = 1 + (rng.next_u64() % 80) as usize;
    Checkpoint {
        round: rng.next_u64() % 10_000,
        next_gap: 1 + rng.next_u64() % 16,
        seed: rng.next_u64(),
        shards: 1 + (rng.next_u64() % 8) as u32,
        lambda: rng.range_f64(1e-6, 1.0),
        updates: rng.next_u64() % 1_000_000,
        r_cur: 1 + rng.next_u64() % 32,
        div_ewma: rng.range_f64(0.0, 2.0),
        tol_hits: (rng.next_u64() % 3) as u32,
        last_objective: if rng.next_f64() < 0.5 {
            None
        } else {
            Some(rng.range_f64(-1e3, 1e3))
        },
        w: (0..n_w).map(|_| rng.range_f64(-1e3, 1e3)).collect(),
        z: (0..n_z).map(|_| rng.range_f64(-1e3, 1e3)).collect(),
    }
}

#[test]
fn checkpoint_fuzz_100_seeds_round_trips_and_survives_corruption() {
    let mut rng = Pcg64::new(0xC4EC, 0x9E37);
    for case in 0..100u32 {
        let ckpt = seeded_checkpoint(&mut rng);
        let bytes = ckpt.encode();
        assert_eq!(bytes.len(), ckpt.encoded_len(), "case {case}");
        let back = Checkpoint::decode(&bytes).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(back, ckpt, "case {case}");
        // every truncation prefix is a typed error, never a panic
        for cut in 0..bytes.len() {
            assert!(
                Checkpoint::decode(&bytes[..cut]).is_err(),
                "case {case}: truncation at {cut} must be rejected"
            );
        }
        // a seeded single-bit flip anywhere is a typed error: the body
        // is CRC-guarded and the trailing CRC guards itself
        let pos = (rng.next_u64() as usize) % bytes.len();
        let mut bad = bytes.clone();
        bad[pos] ^= 1 << (rng.next_u64() % 8);
        match Checkpoint::decode(&bad) {
            Err(_) => {}
            Ok(_) => panic!("case {case}: corrupted byte {pos} must not decode"),
        }
    }
}

#[test]
fn version_bump_is_refused() {
    let mut rng = Pcg64::new(5, 6);
    let mut bytes = seeded_checkpoint(&mut rng).encode();
    bytes[4] = bytes[4].wrapping_add(1); // version lives after the magic
    let body = bytes.len() - 4;
    let crc = gencd::recover::checkpoint::crc32(&bytes[..body]);
    bytes[body..].copy_from_slice(&crc.to_le_bytes());
    assert!(matches!(
        Checkpoint::decode(&bytes),
        Err(CheckpointError::Version(_))
    ));
}

#[test]
fn resume_is_bit_exact_on_every_preset() {
    for alg in PRESETS {
        let ckpt_path = scratch(alg);
        // the uninterrupted reference
        let full = solve_with(alg, 12, None, None);
        assert!(full.failure.is_none(), "{alg}: {:?}", full.failure);
        // the interrupted run: stops at round 5, checkpointing each round
        let cut = solve_with(alg, 5, Some(&ckpt_path), None);
        assert!(cut.failure.is_none(), "{alg}: {:?}", cut.failure);
        assert!(ckpt_path.exists(), "{alg}: no checkpoint written");
        // the resumed run continues to the same cap
        let resumed = solve_with(alg, 12, None, Some(&ckpt_path));
        std::fs::remove_file(&ckpt_path).ok();
        assert!(resumed.failure.is_none(), "{alg}: {:?}", resumed.failure);
        assert_eq!(full.w.len(), resumed.w.len(), "{alg}");
        for (i, (a, b)) in full.w.iter().zip(resumed.w.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{alg}: w[{i}] differs — resume must be bit-exact ({a:e} vs {b:e})"
            );
        }
        assert_eq!(
            full.objective.to_bits(),
            resumed.objective.to_bits(),
            "{alg}: objective must match bitwise"
        );
    }
}

#[test]
fn resume_round_reaches_the_aggregator() {
    let ckpt_path = scratch("agg");
    let cut_agg = MetricsAggregator::new();
    {
        let (x, y) = workload();
        Solver::builder()
            .matrix(x)
            .labels(y)
            .lambda(1e-3)
            .algorithm("shotgun".parse().unwrap())
            .threads(2)
            .shards(2)
            .max_iters(5)
            .tol(0.0)
            .seed(7)
            .checkpoint_path(ckpt_path.clone())
            .checkpoint_every_rounds(1)
            .subscriber(cut_agg.clone())
            .build()
            .unwrap()
            .solve();
    }
    let cut_cols = cut_agg.recover_columns();
    assert!(
        cut_cols.checkpoints_written >= 1,
        "checkpoint writes must be counted, got {cut_cols:?}"
    );
    assert_eq!(cut_cols.resume_round, 0, "fresh solve resumes from nothing");

    let resume_agg = MetricsAggregator::new();
    let (x, y) = workload();
    let out = Solver::builder()
        .matrix(x)
        .labels(y)
        .lambda(1e-3)
        .algorithm("shotgun".parse().unwrap())
        .threads(2)
        .shards(2)
        .max_iters(12)
        .tol(0.0)
        .seed(7)
        .resume_from(ckpt_path.clone())
        .subscriber(resume_agg.clone())
        .build()
        .unwrap()
        .solve();
    std::fs::remove_file(&ckpt_path).ok();
    assert!(out.failure.is_none(), "{:?}", out.failure);
    let cols = resume_agg.recover_columns();
    assert!(
        cols.resume_round >= 5,
        "ResumeLoaded must carry the checkpointed round, got {cols:?}"
    );
}

#[test]
fn builder_refuses_a_mismatched_checkpoint() {
    let ckpt_path = scratch("mismatch");
    let cut = solve_with("shotgun", 5, Some(&ckpt_path), None);
    assert!(cut.failure.is_none(), "{:?}", cut.failure);

    let build_resume = |seed: u64, lambda: f64, shards: usize| {
        let (x, y) = workload();
        Solver::builder()
            .matrix(x)
            .labels(y)
            .lambda(lambda)
            .algorithm("shotgun".parse().unwrap())
            .threads(shards)
            .shards(shards)
            .max_iters(12)
            .tol(0.0)
            .seed(seed)
            .resume_from(ckpt_path.clone())
            .build()
    };
    // the matching configuration is accepted…
    assert!(build_resume(7, 1e-3, 2).is_ok());
    // …and every mismatch is a typed refusal
    for (why, result) in [
        ("seed", build_resume(8, 1e-3, 2)),
        ("lambda", build_resume(7, 1e-2, 2)),
        ("shards", build_resume(7, 1e-3, 3)),
    ] {
        let err = match result {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("{why} mismatch must be refused"),
        };
        assert!(
            err.contains("checkpoint"),
            "{why}: error should name the checkpoint, got {err}"
        );
    }
    std::fs::remove_file(&ckpt_path).ok();
}

#[test]
fn backoff_schedule_is_bounded_and_fast_to_exhaust() {
    let p = ReconnectPolicy::with_attempts(8, 7);
    for a in 0..8 {
        let d = p.delay_ms(a);
        assert!(
            d <= p.cap_ms + p.base_ms / 2,
            "attempt {a}: delay {d} exceeds cap + jitter"
        );
    }
    // exhausting every retry must sit far inside the 30 s degrade
    // ceiling the acceptance bound checks
    assert!(
        p.worst_case_ms() < 30_000,
        "worst case {} ms",
        p.worst_case_ms()
    );
    assert!(!ReconnectPolicy::default().enabled());
}

#[test]
fn transient_disconnect_heals_transparently() {
    let sc = Scenario::load(Path::new("scenarios/net/03-transient-disconnect-heals.toml")).unwrap();
    let run = run_scenario_loopback(&sc).unwrap();
    assert!(run.verdict.pass, "{}", run.verdict.detail);
    let out = run.output.as_ref().unwrap();
    assert!(out.failure.is_none(), "{:?}", out.failure);
    assert_eq!(out.stop, StopReason::MaxIters);
    // the healed run is bit-identical to the fault-free one: the
    // replayed frame carries absolute values
    let mut clean = sc.clone();
    clean.net = Default::default();
    clean.net_reconnect_attempts = 0;
    let base = run_scenario_loopback(&clean).unwrap();
    let (wa, wb) = (
        &base.output.as_ref().unwrap().w,
        &run.output.as_ref().unwrap().w,
    );
    for (i, (a, b)) in wa.iter().zip(wb.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "w[{i}]: heal must be transparent");
    }
}

#[test]
fn reconnect_exhaustion_degrades_promptly_with_link_kind() {
    let sc = Scenario::load(Path::new("scenarios/net/04-reconnect-exhausted.toml")).unwrap();
    let t0 = Instant::now();
    let run = run_scenario_loopback(&sc).unwrap();
    assert!(
        t0.elapsed().as_secs() < 30,
        "exhausted retries must terminate promptly, took {:?}",
        t0.elapsed()
    );
    let out = run.output.as_ref().unwrap();
    assert_eq!(out.stop, StopReason::ShardFailed);
    let failure = out.failure.as_ref().expect("structured error must surface");
    assert_eq!(failure.kind, SolveErrorKind::Link, "{failure}");
    assert!(run.verdict.pass, "{}", run.verdict.detail);
}

#[test]
fn checkpoint_resume_scenario_matches_reference() {
    let sc = Scenario::load(Path::new("scenarios/net/05-checkpoint-resume.toml")).unwrap();
    assert_eq!(sc.resume_at_round, 10);
    let run = run_scenario_loopback(&sc).unwrap();
    assert!(run.verdict.pass, "{}", run.verdict.detail);
    assert!(
        run.verdict.detail.contains("resume_gap"),
        "drill detail should report the gap: {}",
        run.verdict.detail
    );
}

#[test]
fn committed_harness_plans_parse() {
    let expected = [
        ("00-kill9-resume.toml", DrillMode::Kill9Resume),
        ("01-transient-drop.toml", DrillMode::TransientDrop),
        ("02-partition-heal.toml", DrillMode::PartitionHeal),
    ];
    for (file, mode) in expected {
        let path = Path::new("scenarios/harness").join(file);
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let spec = DrillSpec::from_toml_str(&src, file)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert_eq!(spec.mode, mode, "{file}");
        assert!(spec.shards >= 2, "{file}");
        assert!(spec.tolerance > 0.0, "{file}");
    }
}
