//! Differential tests for the PR-5 shard-layer perf levers: NUMA
//! pinning (must be a bit-exact no-op on the math), dirty-chunk delta
//! reconcile (byte-identical to the dense fold), adaptive reconcile
//! cadence (same optimum as every-round reconcile, across all presets),
//! sharded observers, and the adaptive KKT sweep cadence.

use std::ops::ControlFlow;

use gencd::coordinator::algorithms::Algorithm;
use gencd::coordinator::convergence::StopReason;
use gencd::coordinator::observer::IterationInfo;
use gencd::loss::Squared;
use gencd::shard::ShardStrategy;
use gencd::sparse::{CooBuilder, CscMatrix};
use gencd::util::Pcg64;
use gencd::{Solver, SolverBuilder};

/// Random sparse design with a planted 3-coordinate signal (the same
/// construction as `rust/tests/sharding.rs`): squared loss so every
/// execution mode can reach the unique lasso optimum to machine
/// precision.
fn planted_xy(seed: u64, n: usize, k: usize) -> (CscMatrix, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let mut b = CooBuilder::new(n, k);
    for j in 0..k {
        for i in 0..n {
            if rng.next_f64() < 0.25 {
                b.push(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let wstar: Vec<f64> = (0..k).map(|j| if j < 3 { 1.5 } else { 0.0 }).collect();
    let y = x.matvec(&wstar);
    (x, y)
}

/// Two feature blocks over disjoint sample halves: a min-overlap
/// partition makes the shards conflict-free, the low-conflict regime
/// the adaptive cadence is built to exploit.
fn block_xy() -> (CscMatrix, Vec<f64>) {
    let (n_half, k_half) = (30usize, 10usize);
    let mut rng = Pcg64::seeded(5);
    let mut b = CooBuilder::new(2 * n_half, 2 * k_half);
    for j in 0..2 * k_half {
        let (base, jloc) = if j < k_half { (0, j) } else { (n_half, j - k_half) };
        for t in 0..12 {
            b.push(base + (3 * jloc + t) % n_half, j, rng.range_f64(0.2, 1.0));
        }
    }
    let mut x = b.build();
    x.normalize_columns();
    let wstar: Vec<f64> = (0..2 * k_half)
        .map(|j| if j % k_half < 2 { 1.0 } else { 0.0 })
        .collect();
    let y = x.matvec(&wstar);
    (x, y)
}

fn builder(x: &CscMatrix, y: &[f64], alg: Algorithm) -> SolverBuilder {
    Solver::builder()
        .matrix(x.clone())
        .labels(y.to_vec())
        .loss(Squared)
        .lambda(1e-2)
        .algorithm(alg)
        .seed(3)
        .max_seconds(120.0)
        .log_every(500)
}

#[test]
fn numa_pin_is_bit_exact_whatever_the_host() {
    // acceptance criterion: the pinned path must replay the unpinned
    // (PR-3-shaped) sharded engine bit-exactly — pinning moves memory,
    // never arithmetic. Holds on single-node hosts (graceful no-op)
    // and on real multi-node boxes alike.
    let (x, y) = planted_xy(1, 50, 20);
    for alg in [Algorithm::Scd, Algorithm::Shotgun] {
        let plain = builder(&x, &y, alg)
            .shards(2)
            .max_iters(400)
            .build()
            .unwrap()
            .solve();
        let pinned = builder(&x, &y, alg)
            .shards(2)
            .numa_pin(true)
            .max_iters(400)
            .build()
            .unwrap()
            .solve();
        assert_eq!(plain.w, pinned.w, "{}: pinning changed the math", alg.name());
        assert_eq!(plain.objective, pinned.objective, "{}", alg.name());
        assert_eq!(plain.metrics.numa_nodes, 0);
        assert!(pinned.metrics.numa_nodes >= 1, "{}", alg.name());
    }
}

#[test]
fn adaptive_cadence_all_presets_converge_to_every_round_objective() {
    // acceptance criterion: R > 1 (adaptive up to 8 rounds between
    // reconciles) converges within 1e-12 of the unsharded objective on
    // every preset — the cadence can delay cross-shard information,
    // never redirect the fixed point
    let (x, y) = planted_xy(3, 60, 24);
    let iters = 12_000usize;
    for alg in Algorithm::ALL {
        let plain = builder(&x, &y, alg)
            .max_iters(iters)
            .build()
            .unwrap()
            .solve();
        let adaptive = builder(&x, &y, alg)
            .shards(3)
            .threads(3)
            .shard_strategy(ShardStrategy::MinOverlap)
            .reconcile_max_rounds(8)
            .max_iters(iters)
            .build()
            .unwrap()
            .solve();
        assert_eq!(adaptive.metrics.shards, 3, "{}", alg.name());
        let gap = (plain.objective - adaptive.objective).abs();
        assert!(
            gap <= 1e-12,
            "{}: unsharded {} vs adaptive-cadence sharded {} (gap {gap:.3e})",
            alg.name(),
            plain.objective,
            adaptive.objective
        );
    }
}

#[test]
fn adaptive_cadence_skips_rounds_on_low_conflict_data() {
    // block data + min-overlap shards never conflict, so the cadence
    // must back off and actually skip reconciles — the metrics
    // acceptance criterion
    let (x, y) = block_xy();
    let out = builder(&x, &y, Algorithm::Shotgun)
        .shards(2)
        .threads(2)
        .shard_strategy(ShardStrategy::MinOverlap)
        .reconcile_max_rounds(16)
        .max_iters(600)
        .build()
        .unwrap()
        .solve();
    assert_eq!(
        out.metrics.replica_divergence, 0.0,
        "min-overlap shards must not conflict on block data"
    );
    assert!(
        out.metrics.reconcile_rounds_skipped > 0,
        "a conflict-free run must skip reconciles under the adaptive cadence"
    );
    assert!(out.objective.is_finite());
    assert_eq!(out.metrics.iterations, 600, "the cap lands on a reconcile");
}

#[test]
fn fixed_cadence_matches_every_round_at_convergence() {
    // reconcile_every = 4 without adaptation: same optimum as R = 1
    let (x, y) = planted_xy(4, 50, 20);
    let every_round = builder(&x, &y, Algorithm::Ccd)
        .shards(2)
        .max_iters(10_000)
        .build()
        .unwrap()
        .solve();
    let every_fourth = builder(&x, &y, Algorithm::Ccd)
        .shards(2)
        .reconcile_every(4)
        .max_iters(10_000)
        .build()
        .unwrap()
        .solve();
    let gap = (every_round.objective - every_fourth.objective).abs();
    assert!(
        gap <= 1e-12,
        "R=1 {} vs R=4 {} (gap {gap:.3e})",
        every_round.objective,
        every_fourth.objective
    );
    assert!(every_fourth.metrics.reconcile_rounds_skipped > 0);
}

#[test]
fn sharded_observer_streams_and_stops() {
    // the lifted PR-3 restriction: observers run with shards > 1, on
    // the reconciled global iterate, and can stop the solve
    let (x, y) = planted_xy(6, 40, 16);
    let k = x.n_cols();
    let mut calls = 0usize;
    let mut logged = 0usize;
    let out = builder(&x, &y, Algorithm::Shotgun)
        .shards(2)
        .log_every(5)
        .observer(move |info: &IterationInfo<'_>| {
            calls += 1;
            if let Some(obj) = info.objective {
                logged += 1;
                assert!(obj.is_finite());
            }
            assert_eq!(info.state.w_snapshot().len(), k);
            if info.iter >= 20 {
                assert!(calls >= 21 && logged >= 4);
                ControlFlow::Break(())
            } else {
                ControlFlow::Continue(())
            }
        })
        .max_iters(100_000)
        .build()
        .unwrap()
        .solve();
    assert_eq!(out.stop, StopReason::Observer);
    assert_eq!(out.metrics.iterations, 20);
}

#[test]
fn coloring_fast_conflict_free_scatter_agrees_with_scalar() {
    // the fast_kernels extension to the multi-thread conflict-free
    // scatter: COLORING at 4 workers, fast vs scalar — the scatter is
    // bit-identical arithmetic, the gradient gathers re-associate, so
    // the agreement bar is the solve-level one
    let (x, y) = planted_xy(7, 50, 20);
    let run = |fast: bool| {
        builder(&x, &y, Algorithm::Coloring)
            .threads(4)
            .fast_kernels(fast)
            .max_iters(4_000)
            .build()
            .unwrap()
            .solve()
    };
    let scalar = run(false);
    let fast = run(true);
    assert!(
        (scalar.objective - fast.objective).abs() < 1e-9,
        "{} vs {}",
        scalar.objective,
        fast.objective
    );
    for (a, b) in scalar.w.iter().zip(&fast.w) {
        assert!((a - b).abs() < 1e-7, "{a} vs {b}");
    }
}

#[test]
fn adaptive_kkt_through_builder_matches_fixed() {
    // satellite acceptance: adaptive sweep cadence pins the objective
    // within 1e-12 of the fixed cadence, through the public surface
    let (x, y) = planted_xy(8, 50, 20);
    let run = |adaptive: bool| {
        builder(&x, &y, Algorithm::Scd)
            .screening(true)
            .kkt_every(8)
            .kkt_adaptive(adaptive)
            .tol(1e-10)
            .log_every(10)
            .max_iters(usize::MAX)
            .build()
            .unwrap()
            .solve()
    };
    let fixed = run(false);
    let adaptive = run(true);
    assert_eq!(fixed.stop, StopReason::Converged);
    assert_eq!(adaptive.stop, StopReason::Converged);
    assert!(
        (fixed.objective - adaptive.objective).abs() <= 1e-12,
        "fixed {} vs adaptive {}",
        fixed.objective,
        adaptive.objective
    );
}

#[test]
fn numa_pin_with_screening_and_adaptive_cadence_composes() {
    // the whole PR-5 stack at once on the planted problem: pinned,
    // screened, delta-reconciled, adaptively cadenced — still lands on
    // the unsharded optimum
    let (x, y) = planted_xy(9, 60, 24);
    let plain = builder(&x, &y, Algorithm::Shotgun)
        .max_iters(12_000)
        .build()
        .unwrap()
        .solve();
    let full = builder(&x, &y, Algorithm::Shotgun)
        .shards(2)
        .threads(2)
        .numa_pin(true)
        .reconcile_max_rounds(8)
        .screening(true)
        .kkt_every(8)
        .kkt_adaptive(true)
        .max_iters(12_000)
        .build()
        .unwrap()
        .solve();
    let gap = (plain.objective - full.objective).abs();
    assert!(
        gap <= 1e-12,
        "plain {} vs full-stack {} (gap {gap:.3e})",
        plain.objective,
        full.objective
    );
    assert!(full.metrics.numa_nodes >= 1);
    assert!(full.metrics.active_cols > 0);
}
