//! The embeddability contract, exercised from *outside* the crate:
//!
//! * a user-defined `Select` policy (defined in this test file, not in
//!   `src/`) runs through `SolverBuilder` and reproduces the SHOTGUN
//!   preset's trajectory bit-exactly at T=1;
//! * the same TOML/CLI names still reach all eight presets through the
//!   driver, and the driver's results match the builder's bit-exactly;
//! * `build()` rejects each documented incompatible combination;
//! * an `Observer` implements user-side early stopping.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use gencd::config::RunConfig;
use gencd::coordinator::accept::{self, AcceptAll};
use gencd::coordinator::driver;
use gencd::coordinator::select::{self, Select, POLICY_STREAM};
use gencd::prelude::*;

/// A user-side selection policy: wraps the crate's random-subset
/// sampler (seeded through the documented [`POLICY_STREAM`]) and counts
/// invocations — the shape of any real custom policy that adds logic
/// around an existing sampler.
struct CountingShotgunSelect {
    inner: Box<dyn Select>,
    calls: Arc<AtomicUsize>,
}

impl CountingShotgunSelect {
    fn new(k: usize, size: usize, seed: u64, calls: Arc<AtomicUsize>) -> Self {
        // identical stream to the preset: Pcg64::new(seed, POLICY_STREAM)
        let _ = POLICY_STREAM; // the constant is the documented contract
        Self {
            inner: select::random_subset(k, size, seed),
            calls,
        }
    }
}

impl Select for CountingShotgunSelect {
    fn select(&mut self, out: &mut Vec<u32>) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.select(out);
    }

    fn expected_size(&self) -> f64 {
        self.inner.expected_size()
    }

    fn name(&self) -> String {
        "counting-shotgun".into()
    }
}

const SEED: u64 = 7;
const SIZE: usize = 6;

fn dataset() -> gencd::sparse::io::Dataset {
    gencd::data::by_name("dorothea@0.02").unwrap()
}

fn preset_via_builder() -> SolveOutput {
    Solver::builder()
        .dataset(dataset())
        .normalize(true)
        .loss(Logistic)
        .lambda(1e-4)
        .algorithm(Algorithm::Shotgun)
        .select_size(SIZE)
        .seed(SEED)
        .threads(1)
        .max_iters(400)
        .max_seconds(60.0)
        .build()
        .unwrap()
        .solve()
}

#[test]
fn custom_select_matches_shotgun_preset_bit_exactly() {
    let preset = preset_via_builder();

    let calls = Arc::new(AtomicUsize::new(0));
    let ds = dataset();
    let k = ds.n_features();
    let custom = Solver::builder()
        .dataset(ds)
        .normalize(true)
        .loss(Logistic)
        .lambda(1e-4)
        .select(CountingShotgunSelect::new(k, SIZE, SEED, calls.clone()))
        .accept(AcceptAll)
        .threads(1)
        .max_iters(400)
        .max_seconds(60.0)
        .build()
        .unwrap()
        .solve();

    // the custom policy actually drove the solve
    assert_eq!(
        calls.load(Ordering::Relaxed),
        custom.metrics.iterations as usize,
        "one select call per iteration"
    );
    assert!(custom.metrics.iterations > 0);

    // bit-exact: identical weights, objective, and update counts
    assert_eq!(preset.w, custom.w, "weight vectors must match bit-for-bit");
    assert_eq!(preset.objective, custom.objective);
    assert_eq!(preset.metrics.updates, custom.metrics.updates);
    assert_eq!(preset.metrics.iterations, custom.metrics.iterations);

    // and both genuinely descended
    let first = preset.history.records.first().unwrap().objective;
    assert!(preset.objective < first);
}

#[test]
fn driver_toml_name_matches_builder_bit_exactly() {
    // the config surface ("shotgun" by name) routes through the same
    // builder: identical solve results
    let preset = preset_via_builder();

    let mut cfg = RunConfig::default();
    cfg.dataset.name = "dorothea@0.02".into();
    cfg.problem.loss = "logistic".into();
    cfg.problem.lam = 1e-4;
    cfg.solver.algorithm = "shotgun".into();
    cfg.solver.select_size = SIZE;
    cfg.solver.seed = SEED;
    cfg.solver.threads = 1;
    cfg.solver.max_iters = 400;
    cfg.solver.max_seconds = 60.0;
    let res = driver::run(&cfg).unwrap();

    assert_eq!(preset.w, res.w);
    assert_eq!(preset.objective, res.objective);
}

#[test]
fn all_eight_presets_reachable_by_name() {
    // same CLI/TOML names as ever; every preset builds and descends
    for name in [
        "ccd",
        "scd",
        "shotgun",
        "thread-greedy",
        "greedy",
        "coloring",
        "topk",
        "block-shotgun",
    ] {
        let alg: Algorithm = name.parse().unwrap();
        assert_eq!(alg.name(), name);
        let mut cfg = RunConfig::default();
        cfg.dataset.name = "dorothea@0.02".into();
        cfg.problem.lam = 1e-3;
        cfg.solver.algorithm = name.into();
        cfg.solver.threads = 2;
        cfg.solver.max_iters = 60;
        cfg.solver.max_seconds = 20.0;
        let res = driver::run(&cfg).unwrap();
        assert_eq!(res.algorithm, alg);
        let first = res.history.records.first().unwrap().objective;
        assert!(
            res.objective <= first && res.objective.is_finite(),
            "{name}: {first} -> {}",
            res.objective
        );
    }
}

#[test]
fn builder_rejects_each_invalid_combination() {
    let ds = dataset();
    let (x, y) = (ds.x.clone(), ds.y.clone());
    let k = x.n_cols();
    let base = || {
        Solver::builder()
            .matrix(x.clone())
            .labels(y.clone())
            .lambda(1e-4)
    };
    let expect_err = |b: SolverBuilder, needle: &str| {
        let err = b.build().err().unwrap_or_else(|| {
            panic!("combination should be rejected (expected '{needle}')")
        });
        assert!(
            err.to_string().contains(needle),
            "error for '{needle}' was: {err}"
        );
    };

    // no matrix / no labels
    assert!(Solver::builder().labels(y.clone()).build().is_err());
    assert!(Solver::builder().matrix(x.clone()).build().is_err());
    // label count mismatch
    expect_err(
        Solver::builder()
            .matrix(x.clone())
            .labels(vec![1.0; 3])
            .algorithm(Algorithm::Scd),
        "labels",
    );
    // neither preset nor custom policy
    expect_err(base(), "algorithm");
    // preset and custom policy together
    expect_err(
        base()
            .algorithm(Algorithm::Scd)
            .select(select::Cyclic { next: 0, k }),
        "mutually exclusive",
    );
    // custom accept without a select
    expect_err(base().accept(AcceptAll), "needs a .select");
    // preset sizing knobs on a custom policy
    expect_err(
        base().select(select::Cyclic { next: 0, k }).select_size(9),
        "preset sizing",
    );
    expect_err(
        base().select(select::Cyclic { next: 0, k }).accept_k(2),
        "preset sizing",
    );
    // conflict-free updates without the coloring guarantee
    expect_err(
        base()
            .algorithm(Algorithm::Shotgun)
            .select_size(SIZE)
            .threads(4)
            .update_path(UpdatePath::ConflictFree),
        "ConflictFree",
    );
    expect_err(
        base()
            .select(select::Cyclic { next: 0, k })
            .threads(4)
            .update_path(UpdatePath::ConflictFree),
        "ConflictFree",
    );
    // malformed scalars
    expect_err(base().algorithm(Algorithm::Scd).lambda(-0.5), "lambda");
    expect_err(base().algorithm(Algorithm::Scd).lambda(f64::NAN), "lambda");
    expect_err(base().algorithm(Algorithm::Scd).threads(0), "threads");
    expect_err(
        base().algorithm(Algorithm::Scd).warm_start(vec![0.0; 1]),
        "warm start",
    );

    // the valid versions of the above all build
    assert!(base().algorithm(Algorithm::Scd).build().is_ok());
    assert!(base().select(select::Cyclic { next: 0, k }).build().is_ok());
    assert!(base()
        .select(select::Cyclic { next: 0, k })
        .accept(accept::GlobalTopK { k: 2 })
        .build()
        .is_ok());
    assert!(base()
        .algorithm(Algorithm::Coloring)
        .threads(4)
        .update_path(UpdatePath::ConflictFree)
        .build()
        .is_ok());
}

#[test]
fn observer_early_stop_through_builder() {
    let ds = dataset();
    let stopped_at = Arc::new(AtomicUsize::new(0));
    let seen = stopped_at.clone();
    let out = Solver::builder()
        .dataset(ds)
        .normalize(true)
        .lambda(1e-4)
        .algorithm(Algorithm::Scd)
        .threads(2)
        .max_seconds(60.0)
        .observer(move |info: &IterationInfo<'_>| {
            if info.iter >= 37 {
                seen.store(info.iter, Ordering::Relaxed);
                ControlFlow::Break(())
            } else {
                ControlFlow::Continue(())
            }
        })
        .build()
        .unwrap()
        .solve();
    assert_eq!(out.stop, StopReason::Observer);
    assert_eq!(out.metrics.iterations, 37);
    assert_eq!(stopped_at.load(Ordering::Relaxed), 37);
}
