//! Acceptance tests for the wire transports (`gencd::net`):
//!
//! * codec robustness — 100 seeded adversarial delta frames round-trip
//!   bitwise; every truncation and byte corruption of a valid frame
//!   decodes to a clean `DecodeError` (or a valid frame), never a
//!   panic;
//! * loopback parity — routing every reconcile exchange through full
//!   encode→frame→decode reproduces the in-memory barrier bit-for-bit
//!   under `wire_precision = exact`, on **every** `Algorithm` preset,
//!   and stays within 1e-12 of the `BarrierLink` baseline;
//! * f32 quantization stays a *bounded* approximation, not a wrong
//!   answer;
//! * injected message faults (truncation, duplicate delivery, peer
//!   disconnect — the `scenarios/net/` corpus) terminate promptly with
//!   `StopReason::ShardFailed` and a structured, kind-tagged
//!   `SolveError` — degrade, never hang;
//! * the TCP transport solves a real 2-shard localhost exchange
//!   end-to-end and turns a dead peer into a clean link failure.

use std::path::Path;
use std::time::Instant;

use gencd::coordinator::convergence::{SolveErrorKind, StopReason};
use gencd::net::frame::encode_delta;
use gencd::net::{decode_frame, Frame, Transport, WirePrecision};
use gencd::sim::{run_corpus_loopback, run_scenario, run_scenario_loopback, Scenario};
use gencd::sparse::CscMatrix;
use gencd::util::Pcg64;
use gencd::Solver;

/// All eight (Select, Accept) presets, by their registry names.
const PRESETS: [&str; 8] = [
    "ccd",
    "scd",
    "shotgun",
    "thread-greedy",
    "greedy",
    "coloring",
    "topk",
    "block-shotgun",
];

const BASE: &str = r#"
    name = "net-unit-base"
    seed = 5
    [workload]
    kind = "uniform"
    n = 60
    k = 24
    nnz = 6
    lam = 0.001
    [shards]
    count = 2
    [solve]
    rounds = 12
"#;

fn workload() -> (CscMatrix, Vec<f64>) {
    Scenario::from_toml_str(BASE, "x").unwrap().workload()
}

fn solve_with(alg: &str, transport: Transport) -> gencd::coordinator::engine::SolveOutput {
    let (x, y) = workload();
    Solver::builder()
        .matrix(x)
        .labels(y)
        .lambda(1e-3)
        .algorithm(alg.parse().unwrap())
        .threads(2)
        .shards(2)
        .max_iters(12)
        .seed(7)
        .transport(transport)
        .build()
        .unwrap()
        .solve()
}

#[test]
fn seeded_adversarial_frames_round_trip_and_survive_corruption() {
    let mut rng = Pcg64::new(0xC0DEC, 0xF4A3);
    for case in 0..100u32 {
        // adversarial shapes: empty delta, single chunk, bitmap-word
        // boundaries (1024 = 64 chunks), ragged tails
        let n = match case % 7 {
            0 => 0,
            1 => 1,
            2 => 16,
            3 => 1024,
            4 => 1023,
            _ => 1 + (rng.next_u64() % 900) as usize,
        };
        let density = rng.next_f64();
        let dirty: Vec<bool> = (0..n.div_ceil(16))
            .map(|_| rng.next_f64() < density)
            .collect();
        let values: Vec<f64> = (0..n).map(|_| rng.range_f64(-1e6, 1e6)).collect();
        let precision = if case % 2 == 0 {
            WirePrecision::Exact
        } else {
            WirePrecision::F32
        };
        let mut wire = Vec::new();
        encode_delta(
            &mut wire,
            1,
            case as u64,
            precision,
            n,
            |c| dirty.get(c).copied().unwrap_or(false),
            |i| values[i],
        );
        // bitwise round-trip (exact) / quantized round-trip (f32)
        match decode_frame(&wire).unwrap_or_else(|e| panic!("case {case}: {e}")) {
            Frame::Delta(d) => {
                assert_eq!(d.n, n, "case {case}");
                let mut applied = 0usize;
                d.apply(|i, v| {
                    applied += 1;
                    match precision {
                        WirePrecision::Exact => {
                            assert_eq!(v.to_bits(), values[i].to_bits(), "case {case} i={i}")
                        }
                        WirePrecision::F32 => {
                            assert_eq!(v, values[i] as f32 as f64, "case {case} i={i}")
                        }
                    }
                });
                let dirty_elems: usize = (0..n).filter(|i| dirty[i / 16]).count();
                assert_eq!(applied, dirty_elems, "case {case}");
            }
            other => panic!("case {case}: decoded {other:?}"),
        }
        // every truncation is a clean error, never a panic
        for cut in 0..wire.len() {
            assert!(
                decode_frame(&wire[..cut]).is_err(),
                "case {case}: truncation at {cut} must be rejected"
            );
        }
        // single-byte corruption never panics (it may still decode: a
        // flipped value byte is a different, valid frame)
        let pos = (rng.next_u64() as usize) % wire.len().max(1);
        let mut bad = wire.clone();
        bad[pos] ^= 1 << (rng.next_u64() % 8);
        let _ = decode_frame(&bad);
    }
}

#[test]
fn loopback_exact_is_bit_identical_to_barrier_on_every_preset() {
    for alg in PRESETS {
        let a = solve_with(alg, Transport::Barrier);
        let b = solve_with(
            alg,
            Transport::Loopback {
                precision: WirePrecision::Exact,
            },
        );
        assert!(a.failure.is_none(), "{alg}: {:?}", a.failure);
        assert!(b.failure.is_none(), "{alg}: {:?}", b.failure);
        assert!(
            (a.objective - b.objective).abs() <= 1e-12 * a.objective.abs().max(1.0),
            "{alg}: barrier {} vs loopback {}",
            a.objective,
            b.objective
        );
        assert_eq!(a.w.len(), b.w.len(), "{alg}");
        for (i, (x, y)) in a.w.iter().zip(b.w.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{alg}: w[{i}] differs — exact wire must be bit-transparent"
            );
        }
        // the wire was actually exercised, and metrics prove it
        assert!(b.metrics.wire_bytes_tx > 0, "{alg}: no bytes hit the codec");
        assert!(b.metrics.wire_bytes_rx > 0, "{alg}");
        assert_eq!(a.metrics.wire_bytes_tx, 0, "{alg}: barrier has no wire");
    }
}

#[test]
fn loopback_f32_quantization_is_bounded() {
    let a = solve_with("shotgun", Transport::Barrier);
    let b = solve_with(
        "shotgun",
        Transport::Loopback {
            precision: WirePrecision::F32,
        },
    );
    assert!(b.failure.is_none(), "{:?}", b.failure);
    assert!(b.objective.is_finite());
    // f32 replicas perturb the trajectory, but a handful of rounds on a
    // well-conditioned toy problem must stay close to the exact answer
    assert!(
        (a.objective - b.objective).abs() <= 1e-3 * a.objective.abs().max(1.0),
        "exact {} vs f32 {}",
        a.objective,
        b.objective
    );
}

#[test]
fn net_corpus_replays_green_over_loopback() {
    let runs =
        run_corpus_loopback(Path::new("scenarios"), None).expect("scenario dir must be readable");
    // the full barrier corpus (9 scenarios) plus the message-fault
    // corpus under scenarios/net (3 scenarios)
    assert!(
        runs.len() >= 12,
        "loopback corpus must cover scenarios/ and scenarios/net, found {}",
        runs.len()
    );
    for run in &runs {
        assert!(
            run.verdict.pass,
            "scenario {} failed over loopback: {}",
            run.verdict.name, run.verdict.detail
        );
    }
}

#[test]
fn truncated_frame_terminates_structured() {
    let sc = Scenario::load(Path::new("scenarios/net/00-truncated-frame.toml")).unwrap();
    let t0 = Instant::now();
    let run = run_scenario_loopback(&sc).unwrap();
    assert!(
        t0.elapsed().as_secs() < 30,
        "truncated frame must terminate promptly, took {:?}",
        t0.elapsed()
    );
    let out = run.output.as_ref().unwrap();
    assert_eq!(out.stop, StopReason::ShardFailed);
    let failure = out.failure.as_ref().expect("structured error must surface");
    assert_eq!(failure.kind, SolveErrorKind::Protocol, "{failure}");
    assert!(run.verdict.pass, "{}", run.verdict.detail);
}

#[test]
fn duplicate_delivery_is_idempotent_end_to_end() {
    let sc = Scenario::load(Path::new("scenarios/net/01-duplicate-delivery.toml")).unwrap();
    let dup = run_scenario_loopback(&sc).unwrap();
    assert!(dup.verdict.pass, "{}", dup.verdict.detail);
    let out = dup.output.as_ref().unwrap();
    assert!(out.failure.is_none(), "{:?}", out.failure);
    assert!(
        out.metrics.wire_bytes_rx > out.metrics.wire_bytes_tx,
        "the duplicate delivery must show up in rx accounting: tx {} rx {}",
        out.metrics.wire_bytes_tx,
        out.metrics.wire_bytes_rx
    );
    // absolute chunk values: the duplicated round changes nothing
    let mut clean = sc.clone();
    clean.net = Default::default();
    let base = run_scenario_loopback(&clean).unwrap();
    let (wa, wb) = (
        &base.output.as_ref().unwrap().w,
        &dup.output.as_ref().unwrap().w,
    );
    for (i, (x, y)) in wa.iter().zip(wb.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "w[{i}]: duplicate must be a no-op");
    }
}

#[test]
fn peer_disconnect_terminates_structured() {
    let sc = Scenario::load(Path::new("scenarios/net/02-peer-disconnect.toml")).unwrap();
    let t0 = Instant::now();
    let run = run_scenario_loopback(&sc).unwrap();
    assert!(
        t0.elapsed().as_secs() < 30,
        "disconnect must terminate promptly, took {:?}",
        t0.elapsed()
    );
    let out = run.output.as_ref().unwrap();
    assert_eq!(out.stop, StopReason::ShardFailed);
    let failure = out.failure.as_ref().expect("structured error must surface");
    assert_eq!(failure.kind, SolveErrorKind::Link, "{failure}");
    assert!(out.metrics.shard_failures >= 1);
    assert!(run.verdict.pass, "{}", run.verdict.detail);
}

#[test]
fn wire_faults_are_invisible_to_the_barrier_path() {
    // the same net-fault scenario run through the plain (frameless)
    // sim path completes clean: net_* keys only bite on a wire
    let sc = Scenario::load(Path::new("scenarios/net/00-truncated-frame.toml")).unwrap();
    let run = run_scenario(&sc).unwrap();
    let out = run.output.as_ref().unwrap();
    assert!(out.failure.is_none(), "{:?}", out.failure);
    assert_ne!(out.stop, StopReason::ShardFailed);
}

#[test]
fn tcp_two_shard_localhost_smoke() {
    let t0 = Instant::now();
    let out = solve_with(
        "shotgun",
        Transport::Tcp {
            listen: "127.0.0.1:0".into(),
            peers: vec![],
            precision: WirePrecision::Exact,
        },
    );
    assert!(
        t0.elapsed().as_secs() < 60,
        "tcp smoke must not hang, took {:?}",
        t0.elapsed()
    );
    assert!(out.failure.is_none(), "{:?}", out.failure);
    assert!(
        matches!(
            out.stop,
            StopReason::MaxIters | StopReason::Converged | StopReason::Tolerance
        ),
        "unexpected stop: {:?}",
        out.stop
    );
    assert!(out.objective.is_finite());
    assert!(out.metrics.wire_bytes_tx > 0, "no frames crossed the socket");
    // exact precision over TCP is the same float sequence as the barrier
    let base = solve_with("shotgun", Transport::Barrier);
    for (i, (x, y)) in base.w.iter().zip(out.w.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "w[{i}]: tcp exact must match barrier");
    }
}

#[test]
fn tcp_dead_peer_fails_clean_not_hanging() {
    let t0 = Instant::now();
    // port 9 (discard) on localhost: nothing listens there in CI; the
    // dial is refused and the solve must surface a link failure fast
    let out = solve_with(
        "shotgun",
        Transport::Tcp {
            listen: "127.0.0.1:0".into(),
            peers: vec!["127.0.0.1:9".into()],
            precision: WirePrecision::Exact,
        },
    );
    assert!(
        t0.elapsed().as_secs() < 60,
        "dead peer must not hang, took {:?}",
        t0.elapsed()
    );
    assert_eq!(out.stop, StopReason::ShardFailed);
    let failure = out.failure.expect("structured error must surface");
    assert_eq!(failure.kind, SolveErrorKind::Link, "{failure}");
    assert!(
        failure.message.contains("connect"),
        "cause should surface: {failure}"
    );
}
