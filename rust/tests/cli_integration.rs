//! CLI integration: run the built `gencd` binary end-to-end through its
//! subcommands (the way a user drives the system).

use std::process::Command;

fn gencd() -> Command {
    Command::new(env!("CARGO_BIN_EXE_gencd"))
}

fn run_ok(args: &[&str]) -> String {
    let out = gencd().args(args).output().expect("spawn gencd");
    assert!(
        out.status.success(),
        "gencd {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn help_lists_subcommands() {
    let out = run_ok(&["help"]);
    for cmd in [
        "train", "datagen", "color", "spectral", "table3", "fig1", "fig2", "shards",
        "numa",
    ] {
        assert!(out.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn train_runs_and_reports() {
    let out = run_ok(&[
        "train",
        "--dataset",
        "dorothea@0.03",
        "--algorithm",
        "shotgun",
        "--seconds",
        "1",
        "--threads",
        "2",
    ]);
    assert!(out.contains("P* ="), "missing P*: {out}");
    assert!(out.contains("shotgun |"), "missing summary: {out}");
    assert!(out.contains("stop"), "missing stop reason: {out}");
}

#[test]
fn train_sharded_runs() {
    let out = run_ok(&[
        "train",
        "--dataset",
        "dorothea@0.03",
        "--algorithm",
        "shotgun",
        "--seconds",
        "1",
        "--threads",
        "2",
        "--shards",
        "2",
        "--shard-strategy",
        "min-overlap",
    ]);
    assert!(out.contains("shotgun |"), "missing summary: {out}");
    let err = gencd()
        .args(["train", "--dataset", "dorothea@0.03", "--shards", "2", "--shard-strategy", "voronoi", "--seconds", "1"])
        .output()
        .expect("spawn gencd");
    assert!(!err.status.success(), "unknown shard strategy must fail");
}

#[test]
fn train_numa_pinned_with_adaptive_cadence() {
    // the PR-5 flags end-to-end: pinned (no-op on single-node CI),
    // delta-reconciled, adaptive cadence — must run and report
    let out = run_ok(&[
        "train",
        "--dataset",
        "dorothea@0.03",
        "--algorithm",
        "shotgun",
        "--seconds",
        "1",
        "--threads",
        "2",
        "--shards",
        "2",
        "--numa-pin",
        "--reconcile-every",
        "1",
        "--reconcile-max-rounds",
        "8",
    ]);
    assert!(out.contains("shotgun |"), "missing summary: {out}");
    // an inverted cadence window is refused before any threads spawn
    let err = gencd()
        .args([
            "train",
            "--dataset",
            "dorothea@0.03",
            "--reconcile-every",
            "8",
            "--reconcile-max-rounds",
            "2",
            "--seconds",
            "1",
        ])
        .output()
        .expect("spawn gencd");
    assert!(!err.status.success(), "inverted cadence window must fail");
}

#[test]
fn train_with_config_file_and_overrides() {
    let dir = std::env::temp_dir().join("gencd_cli_int");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.toml");
    std::fs::write(
        &cfg,
        r#"
        [dataset]
        name = "reuters@0.02"
        [problem]
        lam = 1e-4
        [solver]
        algorithm = "coloring"
        max_seconds = 1.0
        threads = 2
        "#,
    )
    .unwrap();
    let csv = dir.join("hist.csv");
    let out = run_ok(&[
        "train",
        "--config",
        cfg.to_str().unwrap(),
        "--set",
        "solver.threads=1",
        "--csv",
        csv.to_str().unwrap(),
    ]);
    assert!(out.contains("coloring"), "{out}");
    assert!(out.contains("threads=1"), "{out}");
    let hist = std::fs::read_to_string(&csv).unwrap();
    assert!(hist.starts_with("elapsed_secs,"));
    assert!(hist.lines().count() > 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn datagen_writes_loadable_files() {
    let dir = std::env::temp_dir().join("gencd_cli_datagen");
    std::fs::create_dir_all(&dir).unwrap();
    let bin = dir.join("d.bin");
    run_ok(&[
        "datagen",
        "dorothea",
        "--scale",
        "0.02",
        "--out",
        bin.to_str().unwrap(),
    ]);
    // train from the file
    let out = run_ok(&[
        "train",
        "--set",
        &format!("dataset.path={}", bin.display()),
        "--algorithm",
        "scd",
        "--iters",
        "50",
        "--threads",
        "1",
    ]);
    assert!(out.contains("scd |"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn color_and_spectral_report() {
    let out = run_ok(&["color", "--dataset", "dorothea@0.05", "--strategy", "balanced"]);
    assert!(out.contains("colors"), "{out}");
    let out = run_ok(&["spectral", "--dataset", "dorothea@0.05"]);
    assert!(out.contains("P* ="), "{out}");
}

#[test]
fn unknown_subcommand_fails_cleanly() {
    let out = gencd().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn unknown_flag_fails_cleanly() {
    let out = gencd()
        .args(["train", "--datset", "dorothea@0.02"]) // typo
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown flag"));
}

#[test]
fn artifacts_subcommand_lists_when_built() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = run_ok(&["artifacts", "--smoke"]);
    assert!(out.contains("propose"), "{out}");
    assert!(out.contains("smoke OK"), "{out}");
}
