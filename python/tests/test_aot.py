"""AOT artifact generation: manifest integrity and HLO-text round-trip.

Verifies that the lowered HLO text re-parses through the XLA client and
that executing the artifact (via jax on CPU) matches the oracle — i.e.
what the Rust runtime will load is numerically the model.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def test_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entries = aot.lower_variant("test", aot.VARIANTS["test"], str(out),
                                force=True)
    return str(out), entries


def test_manifest_schema(test_artifacts):
    out, entries = test_artifacts
    assert len(entries) == 6  # 2 losses x {propose, objective, linesearch}
    for e in entries:
        assert e["kind"] in ("propose", "objective", "linesearch")
        assert os.path.exists(os.path.join(out, e["file"]))
        assert len(e["inputs"]) == len(e["input_shapes"])
        # scalars vector is always the last input
        assert e["inputs"][-1] == "scalars"
        assert e["input_shapes"][-1] == [3]


def test_hlo_text_reparses(test_artifacts):
    """The text round-trips through the XLA HLO parser (what Rust does)."""
    out, entries = test_artifacts
    from jax._src.lib import xla_client as xc
    for e in entries:
        text = open(os.path.join(out, e["file"])).read()
        assert "ENTRY" in text and "main" in text
        # jax's bundled client exposes the same parser used by the rust side
        # indirectly; minimally assert structure lines exist per output.
        assert text.count("ROOT") >= 1


def test_hlo_entry_signature(test_artifacts):
    """Entry computation has the manifest's parameter count and a tuple
    root (we lower with return_tuple=True for the rust to_tuple unwrap)."""
    out, entries = test_artifacts
    for e in entries:
        text = open(os.path.join(out, e["file"])).read()
        entry = [ln for ln in text.splitlines() if ln.startswith("ENTRY")]
        assert len(entry) == 1
        sig = entry[0]
        assert sig.count("parameter") == 0  # params listed in body, not sig
        n_params = sum(
            1 for ln in text.splitlines() if " = " in ln and "parameter(" in ln
            and ln.strip().split(" = ")[0].startswith("Arg_")
        ) or sig.count("f32[")
        assert n_params >= len(e["inputs"])


def test_cli_writes_manifest(tmp_path):
    env = dict(os.environ)
    cwd = os.path.join(os.path.dirname(__file__), "..")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--variants", "test"],
        check=True, cwd=cwd, env=env,
    )
    man = json.load(open(tmp_path / "manifest.json"))
    assert man["scalars"] == ["lam", "beta", "inv_n"]
    assert len(man["entries"]) == 6
    # idempotence: second run keeps files (mtime-stable)
    before = {f: os.path.getmtime(tmp_path / f) for f in os.listdir(tmp_path)}
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--variants", "test"],
        check=True, cwd=cwd, env=env,
    )
    for f, t in before.items():
        if f.endswith(".hlo.txt"):
            assert os.path.getmtime(tmp_path / f) == t


def test_artifact_numerics_match_oracle():
    """Execute the lowered computation (jax CPU) and compare to ref.py.

    This is the same HLO the Rust PJRT client runs; numerics here certify
    the artifact, Rust integration tests certify the loader.
    """
    rng = np.random.default_rng(3)
    n, b = 1024, 16
    x = rng.standard_normal((n, b)).astype(np.float32)
    y = np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32)
    z = (rng.standard_normal(n) * 0.4).astype(np.float32)
    mask = np.ones(n, np.float32)
    w = (rng.standard_normal(b) * 0.1).astype(np.float32)
    lam, beta, inv_n = 1e-3, 0.25, 1.0 / n
    sc = np.array([lam, beta, inv_n], np.float32)

    import jax
    fn = jax.jit(model.propose_entry("logistic"))
    g, d, p = fn(x, y, z, mask, w, sc)
    gr, dr, pr = ref.propose_block("logistic", x, y, z, mask, w, lam, beta,
                                   inv_n)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(d, dr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(p, pr, rtol=1e-5, atol=1e-6)
