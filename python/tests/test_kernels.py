"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes-compatible value ranges, and scalar
parameters; every property asserts allclose against ref.py. This is the
core correctness signal for the compute layer (DESIGN.md §5).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels import losses as lk
from compile.kernels import propose as pk

LOSSES = ("squared", "logistic")


def make_problem(seed, n, b, n_real=None):
    rng = np.random.default_rng(seed)
    n_real = n if n_real is None else n_real
    x = rng.standard_normal((n, b)).astype(np.float32)
    x[n_real:, :] = 0.0
    y = np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32)
    z = (rng.standard_normal(n) * 0.5).astype(np.float32)
    mask = (np.arange(n) < n_real).astype(np.float32)
    w = (rng.standard_normal(b) * 0.2).astype(np.float32)
    return x, y, z, mask, w, 1.0 / n_real


shape_strategy = st.tuples(
    st.integers(0, 2**31 - 1),                      # seed
    st.sampled_from([lk.NT, 2 * lk.NT, 3 * lk.NT]),  # padded n
    st.sampled_from([1, 3, 8, 16, pk.BT, 2 * pk.BT]),  # block width
    st.sampled_from(LOSSES),
    st.floats(1e-6, 1e-1),                           # lam
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_propose_block_matches_ref(params):
    seed, n, b, loss, lam = params
    if n % b:  # grad kernel requires b | tiles; any b dividing into bt ok
        b = 16
    x, y, z, mask, w, inv_n = make_problem(seed, n, b, n_real=n - n // 4)
    beta = ref.loss_beta(loss)
    sc = np.array([lam, beta, inv_n], np.float32)
    g, d, p = model.propose_block(loss, x, y, z, mask, w, sc)
    gr, dr, pr = ref.propose_block(loss, x, y, z, mask, w, lam, beta, inv_n)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(d, dr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(p, pr, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(LOSSES))
def test_objective_matches_ref(seed, loss):
    n = lk.NT
    x, y, z, mask, _, inv_n = make_problem(seed, n, 4, n_real=n - 7)
    sc = np.array([0.0, 0.0, inv_n], np.float32)
    (f,) = model.objective_smooth(loss, y, z, mask, sc)
    fr = ref.objective_smooth(loss, y, z, mask, inv_n)
    np.testing.assert_allclose(float(f), float(fr), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(LOSSES),
       st.integers(1, 12))
def test_linesearch_matches_ref(seed, loss, steps):
    n, b = lk.NT, 16
    x, y, z, mask, w, inv_n = make_problem(seed, n, b, n_real=n - 3)
    lam, beta = 1e-3, ref.loss_beta(loss)
    sc = np.array([lam, beta, inv_n], np.float32)
    _, d0, _ = ref.propose_block(loss, x, y, z, mask, w, lam, beta, inv_n)
    (dl,) = model.linesearch(loss, steps, x, y, z, mask, w,
                             np.asarray(d0), sc)
    dlr = ref.linesearch_block(loss, x, y, z, mask, w, jnp.asarray(d0),
                               lam, beta, inv_n, steps)
    np.testing.assert_allclose(dl, dlr, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# analytic invariants of the Eq. (7)/(9) math, independent of the oracle
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.floats(-5, 5), st.floats(-5, 5), st.floats(1e-4, 1.0),
       st.floats(0.1, 4.0))
def test_delta_optimality(w, g, lam, beta):
    """Eq. (7)'s delta minimizes the quadratic upper bound q(d)."""
    w = np.float32(w); g = np.float32(g)
    d = float(ref.propose_delta(w, g, lam, beta))

    def q(dd):
        return 0.5 * beta * dd * dd + g * dd + lam * abs(w + dd)

    base = q(d)
    for step in (1e-3, 1e-2, 0.1):
        assert base <= q(d + step) + 1e-5
        assert base <= q(d - step) + 1e-5


@settings(max_examples=30, deadline=None)
@given(st.floats(-5, 5), st.floats(-5, 5), st.floats(1e-4, 1.0),
       st.floats(0.1, 4.0))
def test_proxy_nonpositive(w, g, lam, beta):
    """phi <= 0: the quadratic-bound decrease is never an increase."""
    w = np.float32(w); g = np.float32(g)
    d = ref.propose_delta(w, g, lam, beta)
    p = float(ref.proxy_phi(w, g, d, lam, beta))
    assert p <= 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(LOSSES))
def test_update_never_increases_objective(seed, loss):
    """Sec. 3.2: a single-coordinate Eq. (7) update cannot increase
    F(w) + lam |w|_1 (the quadratic approximation is an upper bound)."""
    n, b = lk.NT, 8
    x, y, z, mask, w, inv_n = make_problem(seed, n, b)
    lam, beta = 1e-3, ref.loss_beta(loss)
    g, d, _ = ref.propose_block(loss, x, y, z, mask, w, lam, beta, inv_n)
    f0 = float(ref.objective_smooth(loss, y, z, mask, inv_n)) + lam * float(
        np.abs(w).sum())
    j = int(np.argmax(np.abs(np.asarray(d))))
    z1 = z + float(d[j]) * x[:, j]
    w1 = w.copy(); w1[j] += float(d[j])
    f1 = float(ref.objective_smooth(loss, y, z1, mask, inv_n)) + lam * float(
        np.abs(w1).sum())
    assert f1 <= f0 + 1e-6


def test_soft_threshold_equivalence():
    """Sec. 3.1: the clipping form equals the soft-threshold form."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal(100).astype(np.float32)
    g = rng.standard_normal(100).astype(np.float32)
    lam, beta = 0.05, 1.0
    d_clip = ref.propose_delta(w, g, lam, beta)

    def soft(x, tau):
        return np.sign(x) * np.maximum(np.abs(x) - tau, 0.0)

    d_soft = soft(w - g / beta, lam / beta) - w
    np.testing.assert_allclose(d_clip, d_soft, rtol=1e-5, atol=1e-6)


def test_mask_zeroes_padding():
    x, y, z, mask, w, inv_n = make_problem(7, lk.NT, 8, n_real=100)
    beta = 0.25
    sc = np.array([1e-3, beta, inv_n], np.float32)
    g1, _, _ = model.propose_block("logistic", x, y, z, mask, w, sc)
    # corrupt padded region of y/z: results must not change
    y2, z2 = y.copy(), z.copy()
    y2[100:] = 99.0
    z2[100:] = -99.0
    g2, _, _ = model.propose_block("logistic", x, y2, z2, mask, w, sc)
    np.testing.assert_allclose(g1, g2, rtol=0, atol=0)


@pytest.mark.parametrize("loss", LOSSES)
def test_beta_bounds_second_derivative(loss):
    """beta really is an upper bound on ell'' (finite-difference check)."""
    beta = ref.loss_beta(loss)
    ts = np.linspace(-10, 10, 2001)
    for y in (-1.0, 1.0):
        d = np.asarray(ref.loss_deriv(loss, y, ts))
        dd = np.gradient(d, ts)
        assert dd.max() <= beta + 1e-3
