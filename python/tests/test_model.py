"""L2 model-level invariants: composition, tiling edge cases, and the
runtime-scalar contract the Rust side relies on."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import propose as pk
from compile.kernels import ref


def problem(seed=0, n=1024, b=16, n_real=None):
    rng = np.random.default_rng(seed)
    n_real = n if n_real is None else n_real
    x = rng.standard_normal((n, b)).astype(np.float32)
    x[n_real:] = 0.0
    y = np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32)
    z = (rng.standard_normal(n) * 0.4).astype(np.float32)
    mask = (np.arange(n) < n_real).astype(np.float32)
    w = (rng.standard_normal(b) * 0.1).astype(np.float32)
    return x, y, z, mask, w, 1.0 / n_real


def test_tile_validation_rejects_ragged_panels():
    with pytest.raises(ValueError):
        pk._tiles(1000, 16)  # 1000 not divisible by min(1000, 256)


def test_epilogue_rejects_ragged_block():
    g = np.zeros(65, np.float32)  # 65 % 64 != 0
    w = np.zeros(65, np.float32)
    s = np.zeros(3, np.float32)
    with pytest.raises(ValueError):
        pk.propose_epilogue(g, w, s)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([64, 128, 192]))
def test_wide_blocks_tile_correctly(b):
    """Blocks wider than BT exercise the multi-tile grid path."""
    x, y, z, mask, w, inv_n = problem(1, 1024, b)
    sc = np.array([1e-3, 0.25, inv_n], np.float32)
    g, d, p = model.propose_block("logistic", x, y, z, mask, w, sc)
    gr, dr, pr = ref.propose_block("logistic", x, y, z, mask, w, 1e-3, 0.25,
                                   inv_n)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(d, dr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(p, pr, rtol=1e-5, atol=1e-6)


def test_scalars_are_runtime_not_baked():
    """One lowered graph must serve any (lam, beta): the whole point of
    the scalars input (a single artifact serves lambda sweeps)."""
    import jax
    x, y, z, mask, w, inv_n = problem(2)
    fn = jax.jit(model.propose_entry("logistic"))
    for lam in (1e-5, 1e-3, 0.1):
        sc = np.array([lam, 0.25, inv_n], np.float32)
        _, d, _ = fn(x, y, z, mask, w, sc)
        _, dr, _ = ref.propose_block("logistic", x, y, z, mask, w, lam,
                                     0.25, inv_n)
        np.testing.assert_allclose(d, dr, rtol=1e-5, atol=1e-6)


def test_linesearch_zero_delta_fixed_point_squared():
    """For squared loss with beta = ||X_j||^2-consistent scaling, the
    Eq. 7 step from the proposal is already optimal: refinement must not
    move it (mirrors the Rust linesearch test)."""
    x, y, z, mask, w, inv_n = problem(3, 1024, 8)
    # unit-normalize panel columns so a scalar beta is exact
    x = x / np.linalg.norm(x, axis=0, keepdims=True).astype(np.float32)
    beta_eff = inv_n  # squared loss: beta=1, ||X_j||=1 => beta_j = 1/n
    sc = np.array([1e-3, beta_eff, inv_n], np.float32)
    g, d0, _ = model.propose_block("squared", x, y, z, mask, w, sc)
    (d1,) = model.linesearch("squared", 25, x, y, z, mask, w,
                             np.asarray(d0), sc)
    np.testing.assert_allclose(d1, d0, rtol=1e-4, atol=1e-6)


def test_linesearch_descends_1d_objective():
    x, y, z, mask, w, inv_n = problem(4, 1024, 8)
    lam, beta = 1e-3, 0.25
    sc = np.array([lam, beta, inv_n], np.float32)
    g, d0, _ = model.propose_block("logistic", x, y, z, mask, w, sc)
    (d1,) = model.linesearch("logistic", 30, x, y, z, mask, w,
                             np.asarray(d0), sc)

    def obj_1d(delta):
        zj = z[:, None] + x * np.asarray(delta)[None, :]
        v = mask[:, None] * np.asarray(
            ref.loss_value("logistic", y[:, None], zj))
        f = v.sum(axis=0) * inv_n
        return f + lam * np.abs(w + np.asarray(delta))

    f0 = obj_1d(d0)
    f1 = obj_1d(d1)
    assert (f1 <= f0 + 1e-6).all(), (f0 - f1).min()


def test_objective_invariant_to_padded_region():
    x, y, z, mask, w, inv_n = problem(5, 2048, 4, n_real=1500)
    sc = np.array([0.0, 0.0, inv_n], np.float32)
    (f1,) = model.objective_smooth("logistic", y, z, mask, sc)
    y2, z2 = y.copy(), z.copy()
    y2[1500:] = -7.0
    z2[1500:] = 55.0
    (f2,) = model.objective_smooth("logistic", y2, z2, mask, sc)
    assert float(f1) == float(f2)


def test_grad_panel_accumulation_over_many_tiles():
    """n >> NT exercises the accumulator-in-VMEM grid pattern."""
    rng = np.random.default_rng(6)
    n, b = 4096, 32
    x = rng.standard_normal((n, b)).astype(np.float32)
    d = rng.standard_normal(n).astype(np.float32)
    got = pk.grad_panel(x, d)
    want = x.T @ d
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)
