"""Pure-jnp reference oracle for the GenCD compute kernels.

Everything in this file is the *specification*: the Pallas kernels in
``propose.py`` / ``losses.py`` and the Rust sparse propose path are both
tested against these functions.

Notation follows the paper (Scherrer et al., ICML 2012):

  F(w)   = (1/n) sum_i loss(y_i, (Xw)_i)           -- smooth part, Eq. (3)
  delta  = -psi(w_j; (g_j - lam)/beta, (g_j + lam)/beta)   -- Eq. (7)
  phi    = (beta/2) delta^2 + g delta + lam(|w+delta| - |w|)  -- Eq. (9)

where g = grad_j F(w) and psi is the clipping function of Sec. 3.1.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# losses: value and first derivative wrt the fitted value t = (Xw)_i
# ---------------------------------------------------------------------------

def loss_value(name: str, y, t):
    """Pointwise loss ell(y, t)."""
    if name == "squared":
        return 0.5 * (y - t) ** 2
    if name == "logistic":
        # log(1 + exp(-y t)), numerically stable via logaddexp
        return jnp.logaddexp(0.0, -y * t)
    raise ValueError(f"unknown loss {name!r}")


def loss_deriv(name: str, y, t):
    """d/dt ell(y, t)."""
    if name == "squared":
        return t - y
    if name == "logistic":
        # -y * sigmoid(-y t)
        return -y * (1.0 / (1.0 + jnp.exp(y * t)))
    raise ValueError(f"unknown loss {name!r}")


def loss_beta(name: str) -> float:
    """Upper bound on d^2/dt^2 ell(y, t) (Sec. 3.2)."""
    return {"squared": 1.0, "logistic": 0.25}[name]


# ---------------------------------------------------------------------------
# the GenCD Propose math
# ---------------------------------------------------------------------------

def clip_psi(x, a, b):
    """psi(x; a, b): clip x into [a, b] (Sec. 3.1). Requires a <= b."""
    return jnp.clip(x, a, b)


def masked_dloss(name: str, y, z, mask):
    """Masked pointwise loss derivative: mask_i * ell'(y_i, z_i).

    ``mask`` zeroes out padding rows introduced when a dataset's sample
    count is padded up to the artifact's static n.
    """
    return mask * loss_deriv(name, y, z)


def grad_block(x_panel, d, inv_n):
    """g_J = X_J^T d * inv_n for a dense column panel X_J (n x B)."""
    return (x_panel.T @ d) * inv_n


def propose_delta(w, g, lam, beta):
    """Eq. (7): delta = -psi(w; (g-lam)/beta, (g+lam)/beta)."""
    lo = (g - lam) / beta
    hi = (g + lam) / beta
    return -clip_psi(w, lo, hi)


def proxy_phi(w, g, delta, lam, beta):
    """Eq. (9): proxy for the objective decrease (negative is good)."""
    return 0.5 * beta * delta * delta + g * delta + lam * (
        jnp.abs(w + delta) - jnp.abs(w)
    )


def propose_block(name: str, x_panel, y, z, mask, w, lam, beta, inv_n):
    """Full Propose step for a dense block: returns (g, delta, phi)."""
    d = masked_dloss(name, y, z, mask)
    g = grad_block(x_panel, d, inv_n)
    delta = propose_delta(w, g, lam, beta)
    phi = proxy_phi(w, g, delta, lam, beta)
    return g, delta, phi


def objective_smooth(name: str, y, z, mask, inv_n):
    """F(w) evaluated at fitted values z, Eq. (3), padding-masked."""
    return jnp.sum(mask * loss_value(name, y, z)) * inv_n


def linesearch_block(name: str, x_panel, y, z, mask, w, delta0, lam, beta,
                     inv_n, n_steps: int):
    """Per-coordinate quadratic-approximation refinement (paper Sec. 4.1).

    Each coordinate j in the block is refined *independently*: its fitted
    values are z + delta_j X_j (other coordinates held fixed), and the
    Eq. (7) step is re-applied ``n_steps`` times, accumulating the total
    increment. Returns the refined total increment per coordinate.
    """

    def step(delta_tot, _):
        # z_j for every coordinate: (n, B)
        zj = z[:, None] + x_panel * delta_tot[None, :]
        d = mask[:, None] * loss_deriv(name, y[:, None], zj)
        g = jnp.sum(x_panel * d, axis=0) * inv_n
        wj = w + delta_tot
        delta_step = propose_delta(wj, g, lam, beta)
        return delta_tot + delta_step, None

    delta_tot, _ = lax.scan(step, delta0, None, length=n_steps)
    return delta_tot
