"""L1 Pallas kernels: elementwise loss value / derivative over the samples.

These are 1-D elementwise kernels tiled along the sample axis. On a real
TPU the BlockSpec below maps each tile into VMEM (tile size NT is a
multiple of the 128-lane VPU width); on this CPU-only image they run
under ``interpret=True`` (see DESIGN.md §Hardware-Adaptation).

The loss kind is *static* (baked at lowering time) so the generated HLO
contains no branching on the hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sample-axis tile. 1024 f32 lanes = 4 KiB per input tile in VMEM; the
# kernel touches 3 input tiles + 1 output tile = 16 KiB, far under VMEM.
NT = 1024

INTERPRET = True  # CPU image: Mosaic lowering unavailable (see DESIGN.md)


def _dloss_kernel(loss: str, y_ref, z_ref, m_ref, o_ref):
    """o = mask * dl(y, z), one sample tile."""
    y = y_ref[...]
    z = z_ref[...]
    m = m_ref[...]
    if loss == "squared":
        d = z - y
    elif loss == "logistic":
        d = -y * (1.0 / (1.0 + jnp.exp(y * z)))
    else:  # pragma: no cover - static arg validated by callers
        raise ValueError(loss)
    o_ref[...] = m * d


def _loss_kernel(loss: str, y_ref, z_ref, m_ref, o_ref):
    """o = mask * loss(y, z), one sample tile."""
    y = y_ref[...]
    z = z_ref[...]
    m = m_ref[...]
    if loss == "squared":
        v = 0.5 * (y - z) * (y - z)
    elif loss == "logistic":
        v = jnp.logaddexp(0.0, -y * z)
    else:  # pragma: no cover
        raise ValueError(loss)
    o_ref[...] = m * v


def _elementwise_call(kernel, loss: str, y, z, mask):
    n = y.shape[0]
    assert n % NT == 0, f"sample count {n} must be padded to a multiple of {NT}"
    grid = (n // NT,)
    spec = pl.BlockSpec((NT,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(kernel, loss),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), y.dtype),
        interpret=INTERPRET,
    )(y, z, mask)


def masked_dloss(loss: str, y, z, mask):
    """Pallas: mask * ell'(y, z) over padded samples."""
    return _elementwise_call(_dloss_kernel, loss, y, z, mask)


def masked_loss(loss: str, y, z, mask):
    """Pallas: mask * ell(y, z) over padded samples."""
    return _elementwise_call(_loss_kernel, loss, y, z, mask)
