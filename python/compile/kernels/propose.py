"""L1 Pallas kernels for the GenCD Propose step over a dense column panel.

The paper's Propose step (Algorithm 4) is, per selected coordinate j:

    g      = <ell'(y, z), X_j> / n
    delta  = -psi(w_j; (g - lam)/beta, (g + lam)/beta)        (Eq. 7)
    phi    = beta/2 delta^2 + g delta + lam(|w+d| - |w|)      (Eq. 9)

On the OpenMP original this is one sparse column traversal per thread.
The TPU adaptation (DESIGN.md §Hardware-Adaptation) batches a block of B
columns into a dense panel X_J (n x B) and computes all B proposals with
one MXU matvec: the HBM->VMEM schedule that the paper expressed with
threadblocks/threads is expressed here with a BlockSpec grid:

  * ``grad``     — grid (B/BT, n/NT); each step loads an (NT, BT) panel
                   tile + an (NT,) dloss tile into VMEM and accumulates
                   g_tile += X_tile^T d_tile on the MXU. n is the inner
                   (fastest) grid axis so the g tile stays resident.
  * ``epilogue`` — grid (B/BT,); elementwise Eq. 7 + Eq. 9 on the VPU.
  * ``linesearch`` — grid (B/BT,); whole-column panel resident in VMEM,
                   ``n_steps`` fused quadratic-approximation steps per
                   coordinate (paper Sec. 4.1's 500-step refinement).

All kernels run under ``interpret=True`` on this CPU-only image; real-TPU
VMEM/MXU estimates are in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

# Panel tile sizes. NT x BT f32 = 64 KiB in VMEM; both are multiples of
# the TPU-friendly 8x128 register tiling when the block is big enough.
NT = 256
BT = 64


def _tiles(n: int, b: int) -> tuple[int, int]:
    """Pick (nt, bt) tile sizes dividing (n, b), capped at (NT, BT)."""
    nt = min(n, NT)
    bt = min(b, BT)
    if n % nt or b % bt:
        raise ValueError(f"panel ({n},{b}) not divisible by tiles ({nt},{bt})")
    return nt, bt


# ---------------------------------------------------------------------------
# g = X^T d * inv_n  (MXU accumulation kernel)
# ---------------------------------------------------------------------------

def _grad_kernel(x_ref, d_ref, g_ref):
    """One (NT, BT) panel tile: g_tile += x_tile^T @ d_tile.

    The n axis is grid axis 1 (innermost); the output BlockSpec maps every
    n step to the same g tile, so it acts as a VMEM-resident accumulator.
    """
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    g_ref[...] += x_ref[...].T @ d_ref[...]


def grad_panel(x_panel, d):
    """g_raw = X_J^T d for a dense (n, B) panel. Caller scales by inv_n."""
    n, b = x_panel.shape
    nt, bt = _tiles(n, b)
    grid = (b // bt, n // nt)
    return pl.pallas_call(
        _grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nt, bt), lambda j, i: (i, j)),
            pl.BlockSpec((nt,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((b,), x_panel.dtype),
        interpret=INTERPRET,
    )(x_panel, d)


# ---------------------------------------------------------------------------
# epilogue: Eq. (7) + Eq. (9) elementwise over the block
# ---------------------------------------------------------------------------

def _epilogue_kernel(graw_ref, w_ref, s_ref, g_ref, d_ref, p_ref):
    """s_ref holds the runtime scalars [lam, beta, inv_n]."""
    lam = s_ref[0]
    beta = s_ref[1]
    inv_n = s_ref[2]
    g = graw_ref[...] * inv_n
    w = w_ref[...]
    lo = (g - lam) / beta
    hi = (g + lam) / beta
    delta = -jnp.clip(w, lo, hi)
    phi = 0.5 * beta * delta * delta + g * delta + lam * (
        jnp.abs(w + delta) - jnp.abs(w)
    )
    g_ref[...] = g
    d_ref[...] = delta
    p_ref[...] = phi


def propose_epilogue(g_raw, w, scalars):
    """(g, delta, phi) from the raw gradient accumulator.

    ``scalars`` is a (3,) f32 array [lam, beta, inv_n] — runtime values so
    a single AOT artifact serves every (lam, beta) sweep point.
    """
    (b,) = g_raw.shape
    bt = min(b, BT)
    if b % bt:
        raise ValueError(f"block {b} not divisible by tile {bt}")
    grid = (b // bt,)
    vec = pl.BlockSpec((bt,), lambda j: (j,))
    out = jax.ShapeDtypeStruct((b,), g_raw.dtype)
    return pl.pallas_call(
        _epilogue_kernel,
        grid=grid,
        in_specs=[vec, vec, pl.BlockSpec((3,), lambda j: (0,))],
        out_specs=(vec, vec, vec),
        out_shape=(out, out, out),
        interpret=INTERPRET,
    )(g_raw, w, scalars)


# ---------------------------------------------------------------------------
# fused line search (paper Sec. 4.1: repeated quadratic-approximation steps)
# ---------------------------------------------------------------------------

def _linesearch_kernel(loss: str, n_steps: int,
                       x_ref, y_ref, z_ref, m_ref, w_ref, d0_ref, s_ref,
                       out_ref):
    """Refine each coordinate of one BT tile independently, n_steps times.

    The whole (n, bt) column panel stays VMEM-resident across the inner
    fori_loop, so each refinement step is one VPU pass + one reduction —
    no HBM traffic. VMEM budget: n*bt*4 bytes for the panel (documented
    in DESIGN.md §Perf; n is tiled upstream for very large n).
    """
    lam = s_ref[0]
    beta = s_ref[1]
    inv_n = s_ref[2]
    x = x_ref[...]          # (n, bt)
    y = y_ref[...]          # (n,)
    z = z_ref[...]
    m = m_ref[...]
    w = w_ref[...]          # (bt,)

    def step(_, delta_tot):
        zj = z[:, None] + x * delta_tot[None, :]
        if loss == "squared":
            d = zj - y[:, None]
        elif loss == "logistic":
            d = -y[:, None] * (1.0 / (1.0 + jnp.exp(y[:, None] * zj)))
        else:  # pragma: no cover
            raise ValueError(loss)
        d = m[:, None] * d
        g = jnp.sum(x * d, axis=0) * inv_n
        wj = w + delta_tot
        lo = (g - lam) / beta
        hi = (g + lam) / beta
        return delta_tot - jnp.clip(wj, lo, hi)

    out_ref[...] = jax.lax.fori_loop(0, n_steps, step, d0_ref[...])


def linesearch_panel(loss: str, n_steps: int, x_panel, y, z, mask, w, delta0,
                     scalars):
    """Refined total increments for a dense (n, B) panel (see ref.py)."""
    n, b = x_panel.shape
    bt = min(b, BT)
    if b % bt:
        raise ValueError(f"block {b} not divisible by tile {bt}")
    grid = (b // bt,)
    col = pl.BlockSpec((n,), lambda j: (0,))
    vec = pl.BlockSpec((bt,), lambda j: (j,))
    return pl.pallas_call(
        functools.partial(_linesearch_kernel, loss, n_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bt), lambda j: (0, j)),
            col, col, col, vec, vec,
            pl.BlockSpec((3,), lambda j: (0,)),
        ],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((b,), x_panel.dtype),
        interpret=INTERPRET,
    )(x_panel, y, z, mask, w, delta0, scalars)
