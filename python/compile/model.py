"""L2: the GenCD compute graph in JAX, composed from the L1 Pallas kernels.

Three AOT entry points, each lowered once per (dataset, loss) shape
variant by ``aot.py`` and executed from the Rust coordinator via PJRT:

  propose_block   (x, y, z, mask, w, scalars) -> (g, delta, phi)
  objective       (y, z, mask, scalars)       -> (f_smooth,)
  linesearch      (x, y, z, mask, w, d0, scalars) -> (delta_refined,)

``scalars`` is a (3,) f32 array [lam, beta, inv_n]: runtime inputs so one
artifact serves a whole regularization path. All shapes are static at
lowering time (n padded to a multiple of the loss-kernel tile, B the
panel width); Rust pads with zero rows and a zero mask.

Python (this file) never runs on the solve path — see DESIGN.md §2.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import losses as lk
from .kernels import propose as pk


def propose_block(loss: str, x_panel, y, z, mask, w, scalars):
    """Full Propose step (Algorithm 4) for a dense column panel.

    Returns ``(g, delta, phi)`` per coordinate of the block: the scaled
    gradient, the Eq. (7) increment and the Eq. (9) proxy.
    """
    d = lk.masked_dloss(loss, y, z, mask)
    g_raw = pk.grad_panel(x_panel, d)
    return pk.propose_epilogue(g_raw, w, scalars)


def objective_smooth(loss: str, y, z, mask, scalars):
    """F(w) (Eq. 3) from fitted values; the l1 term is added in Rust."""
    inv_n = scalars[2]
    v = lk.masked_loss(loss, y, z, mask)
    return (jnp.sum(v) * inv_n,)


def linesearch(loss: str, n_steps: int, x_panel, y, z, mask, w, delta0,
               scalars):
    """Sec. 4.1 refinement: n_steps quadratic-approximation iterations."""
    return (pk.linesearch_panel(loss, n_steps, x_panel, y, z, mask, w,
                                delta0, scalars),)


def propose_entry(loss: str):
    """Closure with the loss baked in (static), for jax.jit/lower."""

    def fn(x_panel, y, z, mask, w, scalars):
        return propose_block(loss, x_panel, y, z, mask, w, scalars)

    return fn


def objective_entry(loss: str):
    def fn(y, z, mask, scalars):
        return objective_smooth(loss, y, z, mask, scalars)

    return fn


def linesearch_entry(loss: str, n_steps: int):
    def fn(x_panel, y, z, mask, w, delta0, scalars):
        return linesearch(loss, n_steps, x_panel, y, z, mask, w, delta0,
                          scalars)

    return fn
