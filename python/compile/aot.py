"""AOT compiler: lower the L2 entry points to HLO *text* + a manifest.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. Text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts [--variants test]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """jax lowering -> XLA HLO text (see module docstring for why text)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# Shape variants: one set of artifacts per (dataset scale, loss).
# n is the padded sample count (multiple of 1024 = losses.NT); b is the
# dense panel width. ``ls_steps`` is the paper's Sec. 4.1 refinement count.
VARIANTS = {
    "test": dict(n=1024, b=16, losses=("squared", "logistic"), ls_steps=8),
    "dorothea": dict(n=1024, b=64, losses=("logistic", "squared"), ls_steps=500),
    # intermediate paddings so scaled-down runs don't pay full-size
    # panel-gather cost (the runtime picks the smallest fitting n)
    "mid2k": dict(n=2048, b=64, losses=("logistic",), ls_steps=500),
    "mid4k": dict(n=4096, b=64, losses=("logistic",), ls_steps=500),
    "mid8k": dict(n=8192, b=64, losses=("logistic",), ls_steps=500),
    "reuters": dict(n=24576, b=64, losses=("logistic",), ls_steps=500),
}


def lower_variant(name: str, cfg: dict, out_dir: str, force: bool):
    n, b = cfg["n"], cfg["b"]
    entries = []
    for loss in cfg["losses"]:
        jobs = [
            (
                f"propose_{loss}_n{n}_b{b}",
                "propose",
                model.propose_entry(loss),
                [spec(n, b), spec(n), spec(n), spec(n), spec(b), spec(3)],
                ["x_panel", "y", "z", "mask", "w", "scalars"],
                ["g", "delta", "phi"],
                None,
            ),
            (
                f"objective_{loss}_n{n}",
                "objective",
                model.objective_entry(loss),
                [spec(n), spec(n), spec(n), spec(3)],
                ["y", "z", "mask", "scalars"],
                ["f_smooth"],
                None,
            ),
            (
                f"linesearch_{loss}_n{n}_b{b}_s{cfg['ls_steps']}",
                "linesearch",
                model.linesearch_entry(loss, cfg["ls_steps"]),
                [spec(n, b), spec(n), spec(n), spec(n), spec(b), spec(b),
                 spec(3)],
                ["x_panel", "y", "z", "mask", "w", "delta0", "scalars"],
                ["delta_refined"],
                cfg["ls_steps"],
            ),
        ]
        for stem, kind, fn, in_specs, in_names, out_names, steps in jobs:
            path = os.path.join(out_dir, stem + ".hlo.txt")
            if force or not os.path.exists(path):
                lowered = jax.jit(fn).lower(*in_specs)
                text = to_hlo_text(lowered)
                with open(path, "w") as f:
                    f.write(text)
                print(f"  wrote {path} ({len(text)} chars)")
            else:
                print(f"  kept  {path}")
            entry = {
                "variant": name,
                "kind": kind,
                "loss": loss,
                "n": n,
                "b": b,
                "file": stem + ".hlo.txt",
                "inputs": in_names,
                "input_shapes": [list(s.shape) for s in in_specs],
                "outputs": out_names,
            }
            if steps is not None:
                entry["ls_steps"] = steps
            entries.append(entry)
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", nargs="*", default=list(VARIANTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": 1, "scalars": ["lam", "beta", "inv_n"],
                "entries": []}
    for name in args.variants:
        cfg = VARIANTS[name]
        print(f"variant {name}: n={cfg['n']} b={cfg['b']}")
        manifest["entries"].extend(
            lower_variant(name, cfg, args.out_dir, args.force))

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
