//! The three-layer stack in isolation: load the AOT-compiled JAX/Pallas
//! artifacts via PJRT, run the Propose step, the objective, and the
//! 500-step line search, and cross-check each against the pure-Rust
//! sparse implementations.
//!
//!     make artifacts && cargo run --release --example hlo_propose

use std::sync::atomic::Ordering::Relaxed;

use gencd::coordinator::problem::{Problem, SharedState};
use gencd::coordinator::{linesearch, propose};
use gencd::data::{dorothea_like, GenOptions};
use gencd::loss::Logistic;
use gencd::runtime::{HloObjective, HloProposer, Runtime};
use gencd::util::{Pcg64, Timer};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_dir()
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts:");
    for e in &rt.manifest.entries {
        println!("  {:<11} {:<9} n={:<6} b={}", e.kind, e.loss, e.n, e.b);
    }

    // small DOROTHEA twin fits the n=1024 artifacts
    let mut ds = dorothea_like(&GenOptions::with_scale(0.05));
    ds.x.normalize_columns();
    let problem = Problem::new(ds, Box::new(Logistic), 1e-4);
    println!(
        "\nproblem: {} x {}, lam = {:.0e}",
        problem.n_samples(),
        problem.n_features(),
        problem.lam
    );

    // warm start with a few active weights
    let mut rng = Pcg64::seeded(1);
    let w0: Vec<f64> = (0..problem.n_features())
        .map(|j| if j % 113 == 0 { rng.range_f64(-0.4, 0.4) } else { 0.0 })
        .collect();
    let state = SharedState::from_warm_start(&problem, &w0);
    propose::refresh_dloss(&problem, &state, 0, problem.n_samples());

    // ---- Propose: artifact vs sparse Rust --------------------------------
    let mut proposer = HloProposer::new(&rt, &problem)?;
    let selected: Vec<u32> = (0..proposer.block_width() as u32).collect();
    let t = Timer::start();
    let (g, delta, phi) = proposer.run_block(&problem, &state, &selected)?;
    let hlo_secs = t.elapsed_secs();

    let t = Timer::start();
    let mut max_rel = 0.0f64;
    for (i, &j) in selected.iter().enumerate() {
        let sp = propose::propose(&problem, &state, j as usize, true);
        for (a, b) in [
            (g[i] as f64, sp.g),
            (delta[i] as f64, sp.delta),
            (phi[i] as f64, sp.phi),
        ] {
            max_rel = max_rel.max((a - b).abs() / (1.0 + b.abs()));
        }
    }
    let sparse_secs = t.elapsed_secs();
    println!(
        "\npropose block ({} coords): hlo {:.2}ms vs sparse {:.3}ms, max rel err {:.2e}",
        selected.len(),
        hlo_secs * 1e3,
        sparse_secs * 1e3,
        max_rel
    );
    anyhow::ensure!(max_rel < 1e-4, "propose mismatch");

    // ---- Objective --------------------------------------------------------
    let mut obj = HloObjective::new(&rt, &problem)?;
    let z = state.z_snapshot();
    let f_hlo = obj.smooth(&z)?;
    let f_rust = gencd::loss::smooth_part(problem.loss.as_ref(), &problem.y, &z);
    println!("objective: hlo {f_hlo:.6} vs rust {f_rust:.6}");
    anyhow::ensure!((f_hlo - f_rust).abs() < 1e-5);

    // ---- Line search (the 500-step artifact) ------------------------------
    let ls = rt.compile_kind("linesearch", "logistic", problem.n_samples())?;
    let steps = ls.entry.ls_steps.unwrap_or(0);
    let b = ls.entry.b;
    let n_pad = ls.entry.n;
    let js: Vec<u32> = (0..b as u32).collect();
    // panel + padded vectors
    let mut panel = vec![0.0f32; n_pad * b];
    for (col, &j) in js.iter().enumerate() {
        let (rows, vals) = problem.x.col(j as usize);
        for (&i, &v) in rows.iter().zip(vals) {
            panel[i as usize * b + col] = v as f32;
        }
    }
    let mut y_pad = vec![1.0f32; n_pad];
    let mut z_pad = vec![0.0f32; n_pad];
    let mut mask = vec![0.0f32; n_pad];
    for i in 0..problem.n_samples() {
        y_pad[i] = problem.y[i] as f32;
        z_pad[i] = z[i] as f32;
        mask[i] = 1.0;
    }
    let w_blk: Vec<f32> = js
        .iter()
        .map(|&j| state.w[j as usize].load(Relaxed) as f32)
        .collect();
    let delta0: Vec<f32> = js
        .iter()
        .enumerate()
        .map(|(i, _)| delta[i])
        .collect();
    let beta_eff = problem.loss.beta() / problem.n_samples() as f64;
    let scalars = [
        problem.lam as f32,
        beta_eff as f32,
        (1.0 / problem.n_samples() as f64) as f32,
    ];
    let t = Timer::start();
    let outs = ls.run_f32(&[&panel, &y_pad, &z_pad, &mask, &w_blk, &delta0, &scalars])?;
    println!(
        "\nline search ({steps} steps x {b} coords): {:.2}ms via artifact",
        t.elapsed_secs() * 1e3
    );
    let mut max_err = 0.0f64;
    for (i, &j) in js.iter().enumerate() {
        let rust = linesearch::refine(&problem, &state, j as usize, delta0[i] as f64, steps);
        max_err = max_err.max((outs[0][i] as f64 - rust).abs());
    }
    println!("line search max |hlo - rust| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-4, "line search mismatch");

    println!("\nall three artifact kinds match the Rust reference — OK");
    Ok(())
}
