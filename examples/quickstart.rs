//! Quickstart: the GenCD public API in three acts —
//!
//!  1. solve an l1-regularized logistic regression with a named preset
//!     through the typed `Solver` builder;
//!  2. stream per-iteration metrics and stop early with an `Observer`;
//!  3. plug in a *custom* selection policy (the point of the GenCD
//!     framework: Select/Accept are open traits, the named algorithms
//!     are just presets).
//!
//!     cargo run --release --example quickstart

use gencd::prelude::*;

fn main() -> anyhow::Result<()> {
    // A synthetic DOROTHEA twin from the dataset registry. Any CSC
    // matrix + label vector works: .matrix(x).labels(y).
    let ds = gencd::data::by_name("dorothea@0.1")?;

    // ---- 1. named preset through the builder -------------------------
    let res = Solver::builder()
        .dataset(ds.clone())
        .normalize(true) // the paper's column normalization
        .loss(Logistic)
        .lambda(1e-4) // the paper's choice for DOROTHEA
        .algorithm(Algorithm::Shotgun) // or ThreadGreedy | Greedy | Coloring
        .threads(4)
        .line_search_steps(20) // Sec. 4.1 refinement
        .max_seconds(5.0)
        .build()?
        .solve();

    println!("objective      : {:.6}", res.objective);
    println!("nonzero weights: {} / {}", res.nnz, res.w.len());
    println!(
        "updates        : {} ({:.2e}/s)",
        res.metrics.updates,
        res.metrics.updates_per_sec(res.elapsed_secs)
    );
    println!("stopped        : {} after {:.2}s", res.stop, res.elapsed_secs);

    // The convergence history is a plain struct — plot it, store it…
    for r in res.history.records.iter().take(5) {
        println!(
            "  t={:.2}s iter={} obj={:.6} nnz={}",
            r.elapsed_secs, r.iter, r.objective, r.nnz
        );
    }

    // ---- 2. observer: streaming metrics + early stopping -------------
    // Observers run on the leader each iteration; History itself is just
    // the default observer. Returning Break stops the solve.
    let res = Solver::builder()
        .dataset(ds.clone())
        .normalize(true)
        .lambda(1e-4)
        .algorithm(Algorithm::ThreadGreedy)
        .threads(4)
        .max_seconds(30.0)
        .observer(|info: &IterationInfo<'_>| {
            if let Some(obj) = info.objective {
                println!(
                    "  [observer] t={:.2}s iter={} obj={obj:.6} updates={}",
                    info.elapsed_secs, info.iter, info.updates
                );
            }
            if info.iter >= 2000 {
                ControlFlow::Break(()) // user-side stopping rule
            } else {
                ControlFlow::Continue(())
            }
        })
        .build()?
        .solve();
    println!(
        "solve stopped: stop={} after {} iterations (observer breaks at 2000; \
         a slow box may hit max-seconds first)\n",
        res.stop, res.metrics.iterations
    );

    // ---- 3. custom Select policy --------------------------------------
    // Anything implementing `Select` slots into the engine — here a
    // strided sampler; swap in feature clustering, importance sampling…
    struct Strided {
        k: usize,
        stride: usize,
        offset: usize,
    }
    impl Select for Strided {
        fn select(&mut self, out: &mut Vec<u32>) {
            let mut j = self.offset;
            while j < self.k {
                out.push(j as u32);
                j += self.stride;
            }
            self.offset = (self.offset + 1) % self.stride;
        }
        fn expected_size(&self) -> f64 {
            self.k as f64 / self.stride as f64
        }
        fn name(&self) -> String {
            "strided".into()
        }
    }

    let k = ds.n_features();
    let res = Solver::builder()
        .dataset(ds)
        .normalize(true)
        .lambda(1e-4)
        .select(Strided {
            k,
            stride: 64,
            offset: 0,
        })
        .accept(gencd::coordinator::accept::AcceptAll)
        .threads(4)
        .max_seconds(3.0)
        .build()?
        .solve();
    println!(
        "custom Strided policy: obj {:.6}, nnz {}, {} updates",
        res.objective, res.nnz, res.metrics.updates
    );
    Ok(())
}
