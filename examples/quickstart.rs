//! Quickstart: solve an l1-regularized logistic regression with the
//! GenCD public API in ~30 lines.
//!
//!     cargo run --release --example quickstart

use gencd::config::RunConfig;
use gencd::coordinator::driver;

fn main() -> anyhow::Result<()> {
    // Describe the experiment. Everything here can come from a TOML
    // config file (RunConfig::from_file) or CLI overrides instead.
    let mut cfg = RunConfig::default();
    cfg.dataset.name = "dorothea@0.1".into(); // synthetic DOROTHEA twin
    cfg.problem.loss = "logistic".into();
    cfg.problem.lam = 1e-4; // the paper's choice for DOROTHEA
    cfg.solver.algorithm = "shotgun".into(); // or thread-greedy | greedy | coloring
    cfg.solver.threads = 4;
    cfg.solver.max_seconds = 5.0;
    cfg.solver.line_search_steps = 20; // Sec. 4.1 refinement

    let res = driver::run(&cfg)?;

    println!("dataset        : {}", res.dataset);
    if let Some(p) = res.pstar {
        println!("shotgun P*     : {p}");
    }
    println!("objective      : {:.6}", res.objective);
    println!("nonzero weights: {} / {}", res.nnz, res.w.len());
    println!(
        "updates        : {} ({:.2e}/s)",
        res.metrics.updates,
        res.metrics.updates_per_sec(res.elapsed_secs)
    );
    println!("stopped        : {} after {:.2}s", res.stop, res.elapsed_secs);

    // The convergence history is a plain struct — plot it, store it…
    for r in res.history.records.iter().take(5) {
        println!(
            "  t={:.2}s iter={} obj={:.6} nnz={}",
            r.elapsed_secs, r.iter, r.objective, r.nnz
        );
    }
    Ok(())
}
