//! Coloring preprocessing in depth (paper Appendix A + §7): strategy
//! comparison on both dataset twins — colors, balance, time — and the
//! safety property that makes COLORING synchronization-free.
//!
//!     cargo run --release --example coloring_demo

use gencd::bench_harness::Table;
use gencd::coloring::{color_features, verify::verify_coloring, Strategy};
use gencd::data;
use gencd::sparse::RowPattern;

fn main() -> anyhow::Result<()> {
    for name in ["dorothea@0.1", "reuters@0.05"] {
        let mut ds = data::by_name(name)?;
        ds.x.normalize_columns();
        let rows = RowPattern::from_csc(&ds.x);
        println!(
            "\n## {name}: {} x {}, max row degree {} (lower bound on colors)\n",
            ds.n_samples(),
            ds.n_features(),
            rows.max_row_nnz()
        );
        let mut table = Table::new(&[
            "strategy",
            "colors",
            "feat/color",
            "min",
            "max",
            "imbalance",
            "secs",
            "valid",
        ]);
        for strategy in [
            Strategy::Greedy,
            Strategy::GreedyRandomOrder,
            Strategy::LargestFirst,
            Strategy::Balanced,
        ] {
            let c = color_features(&ds.x, strategy, 42);
            let valid = verify_coloring(&ds.x, &c).is_ok();
            table.row(vec![
                strategy.name().into(),
                c.n_colors().to_string(),
                format!("{:.1}", c.mean_class_size()),
                c.min_class_size().to_string(),
                c.max_class_size().to_string(),
                format!("{:.2}", c.imbalance()),
                format!("{:.3}", c.elapsed_secs),
                valid.to_string(),
            ]);
            anyhow::ensure!(valid, "{name}/{}: invalid coloring", strategy.name());
        }
        println!("{}", table.render());
        println!(
            "The paper (§7) notes balanced classes matter more than few colors \
             for parallelism:\nBalanced trades a few extra colors for a \
             max/mean ratio near 1.\n"
        );

        // speculative (Catalyurek-style) parallel coloring: the
        // multi-core algorithm the paper's Appendix A builds on
        println!("speculative parallel coloring (tentative -> detect -> repair):");
        for threads in [1usize, 4, 8] {
            let (c, stats) =
                gencd::coloring::speculative::color_speculative(&ds.x, threads, 0);
            verify_coloring(&ds.x, &c).map_err(|e| anyhow::anyhow!(e))?;
            println!(
                "  T={threads}: {} colors in {} rounds ({} conflicts repaired, {:.3}s)",
                c.n_colors(),
                stats.rounds,
                stats.conflicts,
                c.elapsed_secs
            );
        }
    }
    Ok(())
}
