//! End-to-end driver: the full pipeline on a real (synthetic-twin)
//! workload, proving every layer composes — dataset generation,
//! preprocessing (spectral P*, coloring), all four paper algorithms on
//! both datasets, the AOT/PJRT compute path cross-checked against the
//! sparse path, and a final report with the loss curves.
//!
//!     cargo run --release --example end_to_end
//!
//! Environment: GENCD_E2E_SCALE (default 0.1), GENCD_E2E_SECONDS
//! (default 5.0 per run). Results recorded in EXPERIMENTS.md.

use gencd::bench_harness::Table;
use gencd::config::RunConfig;
use gencd::coordinator::driver::run_on;
use gencd::coordinator::engine::SolveOutput;
use gencd::coordinator::{Algorithm, Problem};
use gencd::data;
use gencd::linalg::{shotgun_pstar, spectral_radius_xtx};
use gencd::loss;
use gencd::prelude::{Logistic, Solver};
use gencd::runtime::{HloProposer, Runtime};
use gencd::util::Timer;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let scale = env_f64("GENCD_E2E_SCALE", 0.1);
    let seconds = env_f64("GENCD_E2E_SECONDS", 5.0);
    let total = Timer::start();
    println!("=== GenCD end-to-end (scale {scale}, {seconds}s/run) ===\n");

    for (name, lam) in [
        ("dorothea", data::dorothea::PAPER_LAMBDA),
        ("reuters", data::reuters::PAPER_LAMBDA),
    ] {
        let dsname = format!("{name}@{scale}");

        // --- stage 1: dataset generation ---------------------------------
        let t = Timer::start();
        let mut ds = data::by_name(&dsname)?;
        ds.x.normalize_columns();
        println!(
            "[{dsname}] generated: {} x {}, {} nnz ({:.1}/feature) in {:.2}s",
            ds.n_samples(),
            ds.n_features(),
            ds.x.nnz(),
            ds.x.mean_col_nnz(),
            t.elapsed_secs()
        );

        // --- stage 2: preprocessing --------------------------------------
        let t = Timer::start();
        let est = spectral_radius_xtx(&ds.x, 100, 1e-7, 1);
        let pstar = shotgun_pstar(ds.n_features(), est.rho);
        println!(
            "[{dsname}] rho = {:.2}, P* = {pstar} ({:.2}s)",
            est.rho,
            t.elapsed_secs()
        );
        let coloring =
            gencd::coloring::color_features(&ds.x, gencd::coloring::Strategy::Greedy, 1);
        gencd::coloring::verify::verify_coloring(&ds.x, &coloring)
            .map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "[{dsname}] coloring: {} colors, {:.1} features/color, {:.2}s (verified)",
            coloring.n_colors(),
            coloring.mean_class_size(),
            coloring.elapsed_secs
        );

        // --- stage 3: train all four paper algorithms --------------------
        // through the typed Solver builder (the embeddable surface; the
        // TOML/CLI driver routes through the same thing)
        let mut table = Table::new(&[
            "algorithm", "objective", "nnz", "updates", "updates/s", "secs", "stop",
        ]);
        let mut results: Vec<(Algorithm, SolveOutput)> = Vec::new();
        for alg in Algorithm::paper_set() {
            let res = Solver::builder()
                .dataset(ds.clone()) // already normalized in stage 1
                .loss(Logistic)
                .lambda(lam)
                .algorithm(alg)
                .threads(4)
                .max_seconds(seconds)
                .line_search_steps(20)
                .seed(7)
                .build()?
                .solve();
            table.row(vec![
                alg.name().to_string(),
                format!("{:.6}", res.objective),
                format!("{}", res.nnz),
                format!("{}", res.metrics.updates),
                format!("{:.2e}", res.metrics.updates_per_sec(res.elapsed_secs)),
                format!("{:.2}", res.elapsed_secs),
                res.stop.to_string(),
            ]);
            results.push((alg, res));
        }
        println!("\n[{dsname}] convergence (lambda = {lam:.0e}):\n{}", table.render());

        // loss curves (head) for the report
        for (alg, res) in &results {
            let pts: Vec<String> = res
                .history
                .records
                .iter()
                .step_by((res.history.records.len() / 6).max(1))
                .map(|r| format!("({:.1}s, {:.4})", r.elapsed_secs, r.objective))
                .collect();
            println!("  {:<13} loss curve: {}", alg.name(), pts.join(" "));
        }

        // all algorithms must have made real progress
        for (alg, res) in &results {
            let first = res.history.records.first().unwrap().objective;
            anyhow::ensure!(
                res.objective < first,
                "{} failed to descend on {dsname}",
                alg.name()
            );
        }

        // --- stage 3b: held-out evaluation of the best model --------------
        let (train, test) = gencd::eval::train_test_split(&ds, 0.25, 11);
        let mut cfg = RunConfig::default();
        cfg.dataset.normalize = false; // ds already normalized
        cfg.problem.lam = lam;
        cfg.solver.algorithm = "thread-greedy".into();
        cfg.solver.threads = 4;
        cfg.solver.max_seconds = seconds;
        cfg.solver.line_search_steps = 20;
        let fit = run_on(&cfg, train, None)?;
        let m = gencd::eval::classification_metrics(
            &test.y,
            &gencd::eval::scores(&test.x, &fit.w),
        );
        println!(
            "[{dsname}] held-out ({} samples): acc {:.3} | P {:.3} R {:.3} F1 {:.3} | AUC {:.3}",
            m.n, m.accuracy, m.precision, m.recall, m.f1, m.auc
        );
        anyhow::ensure!(m.auc > 0.6, "held-out AUC {} too weak", m.auc);
        println!();
    }

    // --- stage 4: the AOT/PJRT path composes with the coordinator -------
    println!("[hlo] cross-checking DenseBlockHlo backend vs sparse path…");
    let mut ds = data::by_name(&format!("dorothea@{}", scale.min(0.05)))?;
    ds.x.normalize_columns();
    let lam = data::dorothea::PAPER_LAMBDA;
    match Runtime::from_default_dir() {
        Ok(rt) => {
            let problem = Problem::new(ds.clone(), loss::by_name("logistic")?, lam);
            let mut proposer = HloProposer::new(&rt, &problem)?;
            let mut cfg = RunConfig::default();
            cfg.dataset.name = ds.name.clone();
            cfg.problem.lam = lam;
            cfg.solver.algorithm = "shotgun".into();
            cfg.solver.threads = 1;
            // equal *work*, not equal wallclock: the two backends run the
            // same deterministic 300 iterations and must land together
            cfg.solver.max_iters = 300;
            cfg.solver.max_seconds = 120.0;
            cfg.solver.select_size = 32;
            let hlo_res = run_on(&cfg, ds.clone(), Some(&mut proposer))?;
            let sparse_res = run_on(&cfg, ds.clone(), None)?;
            println!(
                "  hlo  backend: obj {:.6} ({} artifact calls)",
                hlo_res.objective, proposer.calls
            );
            println!("  rust backend: obj {:.6}", sparse_res.objective);
            let rel = (hlo_res.objective - sparse_res.objective).abs()
                / sparse_res.objective.abs().max(1e-12);
            anyhow::ensure!(rel < 0.05, "backends diverged: rel diff {rel:.3}");
            println!("  backends agree to {:.2}% — OK", rel * 100.0);
        }
        Err(e) => println!("  skipped (artifacts not built: {e})"),
    }

    println!("\n=== end-to-end complete in {:.1}s ===", total.elapsed_secs());
    Ok(())
}
