//! Regularization-path demo: squared-loss Lasso solved over a geometric
//! lambda sweep with warm starts — the pathwise-coordinate-descent
//! workload (Friedman et al. 2007) the paper's Sec. 6 cites, and the
//! decreasing-lambda schedule Bradley et al. suggest for Shotgun
//! (Sec. 4.1) — via the first-class `coordinator::path` API.
//!
//!     cargo run --release --example lasso_pathwise

use gencd::coordinator::path::{lambda_max, solve_path, PathConfig};
use gencd::coordinator::Algorithm;
use gencd::data::{reuters_like, GenOptions};
use gencd::eval;
use gencd::loss;

fn main() -> anyhow::Result<()> {
    // tf-idf-like synthetic data; squared loss on the +-1 labels = Lasso.
    let mut ds = reuters_like(&GenOptions::with_scale(0.05));
    ds.x.normalize_columns();
    let (train, test) = eval::train_test_split(&ds, 0.25, 3);
    println!(
        "dataset: {} train / {} test x {} features, {} nnz",
        train.n_samples(),
        test.n_samples(),
        ds.n_features(),
        ds.x.nnz()
    );
    let sq = loss::by_name("squared")?;
    println!(
        "lambda_max = {:.5}\n",
        lambda_max(&train.x, &train.y, sq.as_ref())
    );

    let cfg = PathConfig {
        algorithm: Algorithm::Shotgun,
        n_points: 8,
        min_ratio: 1e-2,
        threads: 4,
        max_seconds: 2.0,
        tol: 1e-8,
        ..Default::default()
    };
    let path = solve_path(&train, "squared", &cfg)?;

    println!(
        "{:>10} {:>12} {:>7} {:>9} {:>7} {:>9} {:>8}",
        "lambda", "objective", "nnz", "updates", "secs", "test-acc", "test-auc"
    );
    for p in &path {
        let scores = eval::scores(&test.x, &p.w);
        let m = eval::classification_metrics(&test.y, &scores);
        println!(
            "{:>10.2e} {:>12.6} {:>7} {:>9} {:>7.2} {:>9.3} {:>8.3}",
            p.lam, p.objective, p.nnz, p.updates, p.elapsed_secs, m.accuracy, m.auc
        );
    }
    println!(
        "\nNNZ grows as lambda shrinks; held-out AUC peaks mid-path — \
         the lasso path, warm-started."
    );
    Ok(())
}
