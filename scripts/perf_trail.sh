#!/usr/bin/env bash
# perf-trail: keep BENCH_hotpath.json honest.
#
# When a Rust toolchain is available, run the hotpath microbenchmarks
# (now including the `shards` dimension) — the bench overwrites
# BENCH_hotpath.json with real measurements and stamps it "measured by
# cargo bench". When no toolchain exists (e.g. the offline authoring
# containers this repo has been grown in so far), leave the committed
# file alone: it carries an explicit UNMEASURED PLACEHOLDER marker, and
# fabricating numbers would poison the perf trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v cargo >/dev/null 2>&1; then
    echo "perf-trail: toolchain found ($(rustc --version 2>/dev/null || echo 'rustc: unknown')) — running hotpath bench"
    cargo bench --bench hotpath
    if grep -q '"comment": "measured by cargo bench' BENCH_hotpath.json; then
        echo "perf-trail: BENCH_hotpath.json now holds real measurements"
    else
        echo "perf-trail: bench ran but BENCH_hotpath.json lacks the measured marker" >&2
        exit 1
    fi
else
    echo "perf-trail: no Rust toolchain on PATH — keeping the projected placeholder BENCH_hotpath.json" >&2
    if ! grep -q 'UNMEASURED PLACEHOLDER' BENCH_hotpath.json; then
        echo "perf-trail: committed BENCH_hotpath.json is missing its placeholder marker" >&2
        exit 1
    fi
fi
