//! Minimal offline stand-in for the `anyhow` crate, vendored so the
//! workspace builds with no registry access (the same policy as the
//! in-tree `toml`/`json`/`rng` substitutes — see `gencd::util`).
//!
//! Implements exactly the surface the codebase uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, the
//! [`Context`] extension for `Result` and `Option`, and `From`
//! conversions for `?` on standard error types. Not implemented:
//! downcasting, backtraces, `chain()`.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` (the error type defaults like upstream).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error with an optional source, convertible from
/// any `std::error::Error`.
///
/// Deliberately does **not** implement `std::error::Error` itself, so
/// the blanket `From<E: std::error::Error>` impl below stays coherent —
/// the same trick upstream anyhow uses.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro's
    /// worker).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error {
            msg: format!("{c}: {}", self.msg),
            source: self.source,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    // `fn main() -> anyhow::Result<()>` reports through Debug; keep it
    // human-readable and include the source chain when present.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// Context extension: `.context(..)` / `.with_context(|| ..)` on
/// `Result<_, impl std::error::Error>` and `Option<_>`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(Error::from(e).context(f())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err() -> Result<i32> {
        let n: i32 = "nope".parse()?; // From<ParseIntError>
        Ok(n)
    }

    #[test]
    fn from_std_error_and_display() {
        let e = parse_err().unwrap_err();
        assert!(e.to_string().contains("invalid digit"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<i32, std::num::ParseIntError> = "x".parse();
        let e = r.context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));

        let o: Option<i32> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(5).context("fine").unwrap(), 5);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big");
            }
            ensure!(x != 13);
            Ok(x)
        }
        assert_eq!(f(7).unwrap(), 7);
        assert!(f(-1).unwrap_err().to_string().contains("negative input -1"));
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
        assert!(f(13).unwrap_err().to_string().contains("x != 13"));
        let e = anyhow!("value {} at {}", 3, "site");
        assert_eq!(e.to_string(), "value 3 at site");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }
}
